//! Deterministic fault injection and resilience policy — the chaos
//! layer of the fabric.
//!
//! Two halves, one module.  The *attack* half is a seeded [`FaultPlan`]:
//! a declarative list of partial failures — pod crashes mid-batch,
//! latency stragglers, link degradation and partitions, whole-site
//! flaps — that the virtual-time engine ([`super::des`]) schedules on
//! its event heap and the threaded fabric replays on a scaled timer.
//! The *defense* half is [`ResilienceConfig`]: per-request deadlines,
//! bounded retry with exponential backoff + deterministic jitter
//! ([`RetryPolicy`]), tail-latency hedging after an EWMA-derived
//! straggler threshold ([`HedgePolicy`] + [`EwmaLatency`]), a
//! closed→open→half-open [`CircuitBreaker`] per pod/site, and a
//! [`Brownout`] ladder that degrades service under sustained failure
//! and restores it on recovery.
//!
//! Everything here is a pure state machine: no clock, no threads, no
//! I/O.  Callers feed in `now_ms` from whatever [`Clock`] they run on
//! (wall or virtual), which is what lets one implementation back both
//! serving paths — and keeps the DES bit-reproducible.
//!
//! The load-bearing invariant the resilience half exists to uphold:
//! **every admitted request reaches exactly one terminal verdict**
//! (completed, cached, shed, quota-shed, or failed) — nothing lost,
//! nothing double-completed, even when crashes, partitions and a
//! redeploy race mid-storm.  The DES enforces it through its extended
//! conservation check; the threaded path through fan-out accounting.
//!
//! [`Clock`]: super::des::Clock

use std::fmt;

use crate::util::rng::Rng;

// ───────────────────────────── fault plans ─────────────────────────

/// One scheduled partial failure.  Times are virtual seconds from
/// scenario start (the threaded path scales them by its time factor);
/// sites are named so one plan applies to any scenario that hosts them.
#[derive(Debug, Clone)]
pub enum Fault {
    /// Pod `pod` (index within each model group) at `site` crashes at
    /// `at_s`: its in-flight batch fails mid-service (items retried or
    /// failed with a typed verdict), its queue is re-routed, and the
    /// pod rejoins at `restart_s` if given.
    PodCrash {
        /// Crash time, virtual seconds.
        at_s: f64,
        /// Site whose pod crashes.
        site: String,
        /// Pod index within every model group at the site.
        pod: usize,
        /// Optional restart time, virtual seconds (`None` = stays down).
        restart_s: Option<f64>,
    },
    /// Every pod at `site` serves `factor`× slower in `[at_s, until_s)`
    /// — the classic latency straggler.
    Straggler {
        /// Onset, virtual seconds.
        at_s: f64,
        /// End of the slowdown, virtual seconds.
        until_s: f64,
        /// Straggling site.
        site: String,
        /// Multiplicative service-time inflation (> 1).
        factor: f64,
    },
    /// The `a`↔`b` link degrades in `[at_s, until_s)`: RTT inflated by
    /// `rtt_factor`, and each transit loses independently with
    /// probability `loss` (drawn from the plan's seeded chaos stream).
    LinkDegrade {
        /// Onset, virtual seconds.
        at_s: f64,
        /// Healing time, virtual seconds.
        until_s: f64,
        /// One endpoint site.
        a: String,
        /// Other endpoint site.
        b: String,
        /// Multiplicative RTT inflation (≥ 1).
        rtt_factor: f64,
        /// Per-transit loss probability in `[0, 1)`.
        loss: f64,
    },
    /// The `a`↔`b` link is fully partitioned in `[at_s, heal_s)`:
    /// unreachable in both directions until it heals.
    Partition {
        /// Partition time, virtual seconds.
        at_s: f64,
        /// Healing time, virtual seconds.
        heal_s: f64,
        /// One endpoint site.
        a: String,
        /// Other endpoint site.
        b: String,
    },
    /// The whole site drops at `at_s` and recovers at `recover_s` —
    /// a flap racing whatever replanning the control plane attempts.
    SiteFlap {
        /// Loss time, virtual seconds.
        at_s: f64,
        /// Recovery time, virtual seconds.
        recover_s: f64,
        /// Flapping site.
        site: String,
    },
}

/// A named, ordered set of [`Fault`]s — the unit the CLI's `--faults`
/// flag and the canned chaos scenarios pass around.
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    /// Plan name (echoed in reports and error messages).
    pub name: String,
    /// The faults, in declaration order (the engine sorts by time).
    pub faults: Vec<Fault>,
}

/// A typed fault-plan parse failure: which entry, and why.
#[derive(Debug, Clone)]
pub struct FaultParseError {
    /// 1-based entry index within the `;`-separated spec.
    pub entry: usize,
    /// What was wrong.
    pub message: String,
}

impl fmt::Display for FaultParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "fault plan entry {}: {}", self.entry, self.message)
    }
}

impl std::error::Error for FaultParseError {}

fn num(entry: usize, what: &str, v: &str) -> Result<f64, FaultParseError> {
    v.parse().map_err(|_| FaultParseError {
        entry,
        message: format!("bad {what} {v:?} (expected a number)"),
    })
}

impl FaultPlan {
    /// True when the plan injects nothing.
    pub fn is_empty(&self) -> bool {
        self.faults.is_empty()
    }

    /// Resolve a `--faults` argument: the name of a canned plan
    /// (currently `site-loss-storm`) or an inline spec for
    /// [`parse`](Self::parse).
    pub fn named(spec: &str) -> Result<FaultPlan, FaultParseError> {
        match spec {
            "site-loss-storm" => Ok(site_loss_storm_plan()),
            _ => FaultPlan::parse(spec),
        }
    }

    /// Parse an inline plan: `;`-separated entries, each `:`-separated.
    ///
    /// - `crash:SITE:POD:AT[:RESTART]`
    /// - `straggle:SITE:AT:UNTIL:FACTOR`
    /// - `link:A:B:AT:UNTIL:RTT_FACTOR:LOSS`
    /// - `partition:A:B:AT:HEAL`
    /// - `flap:SITE:AT:RECOVER`
    pub fn parse(spec: &str) -> Result<FaultPlan, FaultParseError> {
        let mut faults = Vec::new();
        for (i, entry) in spec.split(';').enumerate() {
            let entry_no = i + 1;
            let entry = entry.trim();
            if entry.is_empty() {
                continue;
            }
            let parts: Vec<&str> = entry.split(':').collect();
            let err = |message: String| FaultParseError { entry: entry_no, message };
            let fault = match parts[0] {
                "crash" => {
                    if parts.len() < 4 || parts.len() > 5 {
                        return Err(err("crash:SITE:POD:AT[:RESTART]".into()));
                    }
                    let pod = parts[2].parse().map_err(|_| FaultParseError {
                        entry: entry_no,
                        message: format!("bad pod index {:?}", parts[2]),
                    })?;
                    Fault::PodCrash {
                        site: parts[1].to_string(),
                        pod,
                        at_s: num(entry_no, "crash time", parts[3])?,
                        restart_s: match parts.get(4) {
                            Some(v) => Some(num(entry_no, "restart time", v)?),
                            None => None,
                        },
                    }
                }
                "straggle" => {
                    if parts.len() != 5 {
                        return Err(err("straggle:SITE:AT:UNTIL:FACTOR".into()));
                    }
                    Fault::Straggler {
                        site: parts[1].to_string(),
                        at_s: num(entry_no, "onset", parts[2])?,
                        until_s: num(entry_no, "end", parts[3])?,
                        factor: num(entry_no, "factor", parts[4])?,
                    }
                }
                "link" => {
                    if parts.len() != 7 {
                        return Err(err("link:A:B:AT:UNTIL:RTT_FACTOR:LOSS".into()));
                    }
                    Fault::LinkDegrade {
                        a: parts[1].to_string(),
                        b: parts[2].to_string(),
                        at_s: num(entry_no, "onset", parts[3])?,
                        until_s: num(entry_no, "end", parts[4])?,
                        rtt_factor: num(entry_no, "rtt factor", parts[5])?,
                        loss: num(entry_no, "loss", parts[6])?,
                    }
                }
                "partition" => {
                    if parts.len() != 5 {
                        return Err(err("partition:A:B:AT:HEAL".into()));
                    }
                    Fault::Partition {
                        a: parts[1].to_string(),
                        b: parts[2].to_string(),
                        at_s: num(entry_no, "partition time", parts[3])?,
                        heal_s: num(entry_no, "heal time", parts[4])?,
                    }
                }
                "flap" => {
                    if parts.len() != 4 {
                        return Err(err("flap:SITE:AT:RECOVER".into()));
                    }
                    Fault::SiteFlap {
                        site: parts[1].to_string(),
                        at_s: num(entry_no, "loss time", parts[2])?,
                        recover_s: num(entry_no, "recovery time", parts[3])?,
                    }
                }
                other => {
                    return Err(err(format!(
                        "unknown fault kind {other:?} \
                         (crash|straggle|link|partition|flap)"
                    )))
                }
            };
            validate(entry_no, &fault)?;
            faults.push(fault);
        }
        Ok(FaultPlan { name: "inline".into(), faults })
    }
}

fn validate(entry: usize, f: &Fault) -> Result<(), FaultParseError> {
    let err = |message: String| Err(FaultParseError { entry, message });
    match f {
        Fault::PodCrash { at_s, restart_s, .. } => {
            if !(*at_s >= 0.0) {
                return err(format!("crash time must be >= 0, got {at_s}"));
            }
            if let Some(r) = restart_s {
                if !(*r > *at_s) {
                    return err(format!("restart {r} must come after the crash {at_s}"));
                }
            }
        }
        Fault::Straggler { at_s, until_s, factor, .. } => {
            if !(*at_s >= 0.0 && *until_s > *at_s) {
                return err(format!("need 0 <= onset < end, got {at_s}..{until_s}"));
            }
            if !(*factor > 1.0) {
                return err(format!("straggler factor must exceed 1, got {factor}"));
            }
        }
        Fault::LinkDegrade { at_s, until_s, rtt_factor, loss, .. } => {
            if !(*at_s >= 0.0 && *until_s > *at_s) {
                return err(format!("need 0 <= onset < end, got {at_s}..{until_s}"));
            }
            if !(*rtt_factor >= 1.0) {
                return err(format!("rtt factor must be >= 1, got {rtt_factor}"));
            }
            if !(*loss >= 0.0 && *loss < 1.0) {
                return err(format!("loss must be in [0, 1), got {loss}"));
            }
        }
        Fault::Partition { at_s, heal_s, .. } => {
            if !(*at_s >= 0.0 && *heal_s > *at_s) {
                return err(format!("need 0 <= partition < heal, got {at_s}..{heal_s}"));
            }
        }
        Fault::SiteFlap { at_s, recover_s, .. } => {
            if !(*at_s >= 0.0 && *recover_s > *at_s) {
                return err(format!("need 0 <= loss < recovery, got {at_s}..{recover_s}"));
            }
        }
    }
    Ok(())
}

/// The canned failure storm the `site-loss-storm` scenario and the
/// BENCH `resilience` verdicts ride: a straggling edge, a far-edge pod
/// crash with restart, a cloud↔far-edge partition, a degraded
/// edge↔cloud link, and a far-edge flap — all overlapping the
/// scenario's flash crowd and racing its own site-loss drill and the
/// autoscaler's redeploys.
pub fn site_loss_storm_plan() -> FaultPlan {
    FaultPlan {
        name: "site-loss-storm".into(),
        faults: vec![
            Fault::Straggler {
                at_s: 620.0,
                until_s: 900.0,
                site: "edge".into(),
                factor: 6.0,
            },
            Fault::PodCrash {
                at_s: 650.0,
                site: "far-edge".into(),
                pod: 0,
                restart_s: Some(760.0),
            },
            Fault::Partition {
                at_s: 700.0,
                heal_s: 820.0,
                a: "cloud".into(),
                b: "far-edge".into(),
            },
            Fault::LinkDegrade {
                at_s: 840.0,
                until_s: 980.0,
                a: "edge".into(),
                b: "cloud".into(),
                rtt_factor: 3.0,
                loss: 0.05,
            },
            Fault::SiteFlap {
                at_s: 950.0,
                recover_s: 1050.0,
                site: "far-edge".into(),
            },
        ],
    }
}

// ──────────────────────────── retry policy ─────────────────────────

/// Bounded retry with exponential backoff and deterministic jitter.
#[derive(Debug, Clone)]
pub struct RetryPolicy {
    /// Retries after the first attempt fails (0 disables retry).
    pub max_retries: u32,
    /// First backoff, ms; doubles per retry.
    pub base_ms: f64,
    /// Backoff ceiling, ms.
    pub max_backoff_ms: f64,
    /// Per-request deadline from admission, ms (`0` = none): once
    /// exceeded, the next failure is terminal instead of retried.
    pub deadline_ms: f64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy { max_retries: 2, base_ms: 5.0, max_backoff_ms: 200.0, deadline_ms: 0.0 }
    }
}

impl RetryPolicy {
    /// Backoff before retry number `retry` (1-based): exponential,
    /// capped, with multiplicative jitter in `[0.5, 1.0)` drawn from
    /// the caller's seeded stream.
    pub fn backoff_ms(&self, retry: u32, rng: &mut Rng) -> f64 {
        let exp = self.base_ms * 2f64.powi(retry.saturating_sub(1).min(16) as i32);
        exp.min(self.max_backoff_ms) * rng.range_f64(0.5, 1.0)
    }

    /// Whether a request admitted at `enq_ms` may still retry at
    /// `now_ms` for retry number `retry`.
    pub fn may_retry(&self, retry: u32, enq_ms: f64, now_ms: f64) -> bool {
        retry <= self.max_retries
            && (self.deadline_ms <= 0.0 || now_ms - enq_ms < self.deadline_ms)
    }
}

// ──────────────────────────── hedging ──────────────────────────────

/// Tail-latency hedging: duplicate a request to the next-ranked
/// pod/site once it has been outstanding past a straggler threshold;
/// first copy to finish wins, the loser is cancelled and accounted.
#[derive(Debug, Clone)]
pub struct HedgePolicy {
    /// Fixed straggler threshold, ms — `0` derives it from the service
    /// EWMA instead ([`EwmaLatency::threshold_ms`]).
    pub threshold_ms: f64,
    /// EWMA multiple that counts as straggling when `threshold_ms` is 0.
    pub ewma_multiplier: f64,
}

impl Default for HedgePolicy {
    fn default() -> Self {
        HedgePolicy { threshold_ms: 0.0, ewma_multiplier: 3.0 }
    }
}

/// Exponentially weighted service-latency estimate feeding the hedge
/// threshold — the same smoothing shape the router's feedback uses.
#[derive(Debug, Clone)]
pub struct EwmaLatency {
    /// Current estimate, ms (meaningless until `seen`).
    pub ewma_ms: f64,
    alpha: f64,
    seen: bool,
}

impl EwmaLatency {
    /// An estimator with smoothing factor `alpha` in `(0, 1]`.
    pub fn new(alpha: f64) -> EwmaLatency {
        EwmaLatency { ewma_ms: 0.0, alpha: alpha.clamp(1e-3, 1.0), seen: false }
    }

    /// Fold one observed service latency into the estimate.
    pub fn observe(&mut self, ms: f64) {
        if self.seen {
            self.ewma_ms += self.alpha * (ms - self.ewma_ms);
        } else {
            self.ewma_ms = ms;
            self.seen = true;
        }
    }

    /// The hedge-fire threshold under `pol`: the fixed threshold when
    /// set, otherwise `ewma × multiplier` — infinite (never hedge)
    /// before the first observation.
    pub fn threshold_ms(&self, pol: &HedgePolicy) -> f64 {
        if pol.threshold_ms > 0.0 {
            pol.threshold_ms
        } else if self.seen {
            self.ewma_ms * pol.ewma_multiplier
        } else {
            f64::INFINITY
        }
    }
}

// ─────────────────────────── circuit breaker ───────────────────────

/// Breaker configuration: when to trip, how long to stay open, how
/// many probes half-open admits.
#[derive(Debug, Clone)]
pub struct BreakerConfig {
    /// Consecutive failures that trip the breaker open.
    pub consecutive_failures: u32,
    /// How long the breaker stays open before probing, ms.
    pub open_ms: f64,
    /// Probe requests admitted while half-open; one success closes,
    /// any failure re-trips.
    pub half_open_probes: u32,
}

impl Default for BreakerConfig {
    fn default() -> Self {
        BreakerConfig { consecutive_failures: 3, open_ms: 5_000.0, half_open_probes: 1 }
    }
}

/// Breaker state, in the canonical closed→open→half-open cycle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BreakerState {
    /// Healthy: all traffic admitted.
    Closed,
    /// Tripped: all traffic refused until `open_ms` elapses.
    Open,
    /// Probing: a bounded number of requests admitted; one success
    /// closes the breaker, any failure re-trips it.
    HalfOpen,
}

/// A per-pod/per-site circuit breaker.  Transitions are lazy — driven
/// by [`allow`](Self::allow)/[`on_failure`](Self::on_failure) calls
/// with the caller's clock — so the same machine runs on wall and
/// virtual time.
#[derive(Debug, Clone)]
pub struct CircuitBreaker {
    cfg: BreakerConfig,
    state: BreakerState,
    consecutive: u32,
    opened_at_ms: f64,
    probes_left: u32,
    trips: u64,
}

impl CircuitBreaker {
    /// A closed breaker under `cfg`.
    pub fn new(cfg: BreakerConfig) -> CircuitBreaker {
        CircuitBreaker {
            cfg,
            state: BreakerState::Closed,
            consecutive: 0,
            opened_at_ms: 0.0,
            probes_left: 0,
            trips: 0,
        }
    }

    /// May a request be routed through this breaker at `now_ms`?
    /// Open breakers transition to half-open once `open_ms` has
    /// elapsed; half-open admits up to `half_open_probes` requests.
    pub fn allow(&mut self, now_ms: f64) -> bool {
        if self.state == BreakerState::Open {
            if now_ms - self.opened_at_ms >= self.cfg.open_ms {
                self.state = BreakerState::HalfOpen;
                self.probes_left = self.cfg.half_open_probes.max(1);
            } else {
                return false;
            }
        }
        match self.state {
            BreakerState::Closed => true,
            BreakerState::HalfOpen => {
                if self.probes_left > 0 {
                    self.probes_left -= 1;
                    true
                } else {
                    false
                }
            }
            BreakerState::Open => unreachable!("handled above"),
        }
    }

    /// Record a success: closes a half-open breaker, clears the
    /// failure streak.
    pub fn on_success(&mut self) {
        self.consecutive = 0;
        if self.state == BreakerState::HalfOpen {
            self.state = BreakerState::Closed;
        }
    }

    /// Record a failure at `now_ms`: re-trips a half-open breaker
    /// immediately, trips a closed one after the configured streak.
    pub fn on_failure(&mut self, now_ms: f64) {
        match self.state {
            BreakerState::HalfOpen => self.trip(now_ms),
            BreakerState::Closed => {
                self.consecutive += 1;
                if self.consecutive >= self.cfg.consecutive_failures {
                    self.trip(now_ms);
                }
            }
            BreakerState::Open => {}
        }
    }

    fn trip(&mut self, now_ms: f64) {
        self.state = BreakerState::Open;
        self.opened_at_ms = now_ms;
        self.consecutive = 0;
        self.probes_left = 0;
        self.trips += 1;
    }

    /// Current state (lazy: an open breaker past its window still
    /// reads `Open` until the next [`allow`](Self::allow)).
    pub fn state(&self) -> BreakerState {
        self.state
    }

    /// Times this breaker has tripped open.
    pub fn trips(&self) -> u64 {
        self.trips
    }

    /// True when the breaker is closed (healthy).
    pub fn is_closed(&self) -> bool {
        self.state == BreakerState::Closed
    }
}

// ───────────────────────────── brownout ────────────────────────────

/// Brownout ladder configuration: windowed failure-rate thresholds for
/// stepping degradation up and down.
#[derive(Debug, Clone)]
pub struct BrownoutConfig {
    /// Window failure rate at or above which the ladder steps up.
    pub enter_failure_rate: f64,
    /// Window failure rate at or below which the ladder steps down.
    pub exit_failure_rate: f64,
    /// Deepest degradation level (1 = smaller batches, 2 = + cheaper
    /// variant, 3 = + shed lowest-priority demand).
    pub max_level: u8,
}

impl Default for BrownoutConfig {
    fn default() -> Self {
        BrownoutConfig { enter_failure_rate: 0.2, exit_failure_rate: 0.02, max_level: 3 }
    }
}

/// Brownout state for one site/fleet: observations accumulate into the
/// current window; each [`tick`](Self::tick) converts the window's
/// failure rate into at most one ladder step.  Time spent at any
/// degraded level accumulates into `total_ms`.
#[derive(Debug, Clone)]
pub struct Brownout {
    cfg: BrownoutConfig,
    level: u8,
    ok: u64,
    err: u64,
    entered_at_ms: f64,
    total_ms: f64,
}

impl Brownout {
    /// A healthy (level 0) ladder under `cfg`.
    pub fn new(cfg: BrownoutConfig) -> Brownout {
        Brownout { cfg, level: 0, ok: 0, err: 0, entered_at_ms: 0.0, total_ms: 0.0 }
    }

    /// Record one request outcome into the current window.
    pub fn observe(&mut self, ok: bool) {
        if ok {
            self.ok += 1;
        } else {
            self.err += 1;
        }
    }

    /// Close the current window at `now_ms` and step the ladder at
    /// most one level; returns the level now in force.  An empty
    /// window counts as healthy (rate 0) so recovery is automatic once
    /// failures stop.
    pub fn tick(&mut self, now_ms: f64) -> u8 {
        let total = self.ok + self.err;
        let rate = if total == 0 { 0.0 } else { self.err as f64 / total as f64 };
        if rate >= self.cfg.enter_failure_rate && self.level < self.cfg.max_level {
            if self.level == 0 {
                self.entered_at_ms = now_ms;
            }
            self.level += 1;
        } else if rate <= self.cfg.exit_failure_rate && self.level > 0 {
            self.level -= 1;
            if self.level == 0 {
                self.total_ms += now_ms - self.entered_at_ms;
            }
        }
        self.ok = 0;
        self.err = 0;
        self.level
    }

    /// Current degradation level (0 = full service).
    pub fn level(&self) -> u8 {
        self.level
    }

    /// Total degraded time through `now_ms`, ms (closes the open
    /// interval without mutating state).
    pub fn degraded_ms(&self, now_ms: f64) -> f64 {
        if self.level > 0 {
            self.total_ms + (now_ms - self.entered_at_ms)
        } else {
            self.total_ms
        }
    }
}

// ─────────────────────────── resilience policy ─────────────────────

/// The resilience knobs a serving path runs under.  Everything
/// defaults to off, so plain scenarios are byte-identical to their
/// pre-chaos selves.
#[derive(Debug, Clone, Default)]
pub struct ResilienceConfig {
    /// Bounded retry with backoff (`None` = fail fast).
    pub retry: Option<RetryPolicy>,
    /// Tail-latency hedging (`None` = never duplicate).
    pub hedge: Option<HedgePolicy>,
    /// Per-pod/per-site circuit breaking (`None` = always route).
    pub breaker: Option<BreakerConfig>,
    /// Brownout degradation ladder (`None` = never degrade).
    pub brownout: Option<BrownoutConfig>,
}

impl ResilienceConfig {
    /// True when any resilience mechanism is enabled.
    pub fn any_on(&self) -> bool {
        self.retry.is_some()
            || self.hedge.is_some()
            || self.breaker.is_some()
            || self.brownout.is_some()
    }

    /// The defaults the canned chaos scenarios run under: retry,
    /// EWMA-derived hedging, breakers, and the brownout ladder all on.
    pub fn storm_defaults() -> ResilienceConfig {
        ResilienceConfig {
            retry: Some(RetryPolicy::default()),
            hedge: Some(HedgePolicy::default()),
            breaker: Some(BreakerConfig::default()),
            brownout: Some(BrownoutConfig::default()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_parses_every_kind_and_rejects_garbage() {
        let plan = FaultPlan::parse(
            "crash:edge:0:5;crash:edge:1:5:9.5;straggle:cloud:1:4:6;\
             link:edge:cloud:2:8:3:0.1;partition:a:b:1:2;flap:edge:3:7",
        )
        .unwrap();
        assert_eq!(plan.faults.len(), 6);
        assert!(matches!(
            plan.faults[1],
            Fault::PodCrash { restart_s: Some(r), .. } if (r - 9.5).abs() < 1e-9
        ));
        for bad in [
            "warp:edge:1:2",              // unknown kind
            "crash:edge:x:5",             // bad pod index
            "crash:edge:0:5:4",           // restart before crash
            "straggle:cloud:4:1:6",       // end before onset
            "straggle:cloud:1:4:0.5",     // factor <= 1
            "link:a:b:1:4:3:1.5",         // loss out of range
            "partition:a:b:5:5",          // zero-length partition
            "flap:edge:3",                // missing field
        ] {
            let err = FaultPlan::parse(bad).unwrap_err();
            assert_eq!(err.entry, 1, "{bad}: {err}");
        }
    }

    #[test]
    fn named_resolves_the_canned_storm() {
        let plan = FaultPlan::named("site-loss-storm").unwrap();
        assert_eq!(plan.name, "site-loss-storm");
        assert!(plan.faults.len() >= 5);
    }

    #[test]
    fn backoff_grows_caps_and_jitters_deterministically() {
        let pol = RetryPolicy { base_ms: 10.0, max_backoff_ms: 55.0, ..Default::default() };
        let mut a = Rng::new(1);
        let mut b = Rng::new(1);
        let b1 = pol.backoff_ms(1, &mut a);
        let b2 = pol.backoff_ms(2, &mut a);
        let b3 = pol.backoff_ms(5, &mut a);
        assert!((5.0..10.0).contains(&b1), "{b1}");
        assert!((10.0..20.0).contains(&b2), "{b2}");
        assert!((27.5..55.0).contains(&b3), "capped then jittered: {b3}");
        assert_eq!(b1, pol.backoff_ms(1, &mut b), "same seed, same jitter");
    }

    #[test]
    fn retry_honors_bounds_and_deadline() {
        let pol = RetryPolicy { max_retries: 2, deadline_ms: 100.0, ..Default::default() };
        assert!(pol.may_retry(1, 0.0, 50.0));
        assert!(pol.may_retry(2, 0.0, 50.0));
        assert!(!pol.may_retry(3, 0.0, 50.0), "retry budget spent");
        assert!(!pol.may_retry(1, 0.0, 100.0), "deadline exceeded");
    }

    #[test]
    fn breaker_walks_closed_open_half_open_closed() {
        let mut b = CircuitBreaker::new(BreakerConfig {
            consecutive_failures: 2,
            open_ms: 100.0,
            half_open_probes: 1,
        });
        assert!(b.allow(0.0));
        b.on_failure(0.0);
        assert_eq!(b.state(), BreakerState::Closed, "one failure is not a streak");
        b.on_failure(1.0);
        assert_eq!(b.state(), BreakerState::Open);
        assert_eq!(b.trips(), 1);
        assert!(!b.allow(50.0), "open window holds");
        assert!(b.allow(101.0), "half-open admits a probe");
        assert_eq!(b.state(), BreakerState::HalfOpen);
        assert!(!b.allow(102.0), "probe budget is 1");
        b.on_success();
        assert!(b.is_closed());
        assert_eq!(b.trips(), 1);
    }

    #[test]
    fn half_open_failure_re_trips() {
        let mut b = CircuitBreaker::new(BreakerConfig {
            consecutive_failures: 1,
            open_ms: 10.0,
            half_open_probes: 1,
        });
        b.on_failure(0.0);
        assert!(b.allow(11.0));
        b.on_failure(11.0);
        assert_eq!(b.state(), BreakerState::Open);
        assert_eq!(b.trips(), 2);
        assert!(!b.allow(12.0));
    }

    #[test]
    fn success_interleaving_resets_the_streak() {
        let mut b = CircuitBreaker::new(BreakerConfig {
            consecutive_failures: 3,
            ..Default::default()
        });
        b.on_failure(0.0);
        b.on_failure(1.0);
        b.on_success();
        b.on_failure(2.0);
        b.on_failure(3.0);
        assert!(b.is_closed(), "streak broke; 2 < 3 since the success");
    }

    #[test]
    fn ewma_threshold_derives_from_observations() {
        let pol = HedgePolicy { threshold_ms: 0.0, ewma_multiplier: 3.0 };
        let mut e = EwmaLatency::new(0.3);
        assert_eq!(e.threshold_ms(&pol), f64::INFINITY, "never hedge blind");
        e.observe(10.0);
        assert!((e.threshold_ms(&pol) - 30.0).abs() < 1e-9);
        e.observe(20.0);
        let expect = (10.0 + 0.3 * 10.0) * 3.0;
        assert!((e.threshold_ms(&pol) - expect).abs() < 1e-9);
        let fixed = HedgePolicy { threshold_ms: 7.0, ewma_multiplier: 3.0 };
        assert_eq!(e.threshold_ms(&fixed), 7.0, "fixed threshold wins");
    }

    #[test]
    fn brownout_ladder_steps_up_under_failure_and_recovers() {
        let mut b = Brownout::new(BrownoutConfig {
            enter_failure_rate: 0.5,
            exit_failure_rate: 0.1,
            max_level: 2,
        });
        for _ in 0..4 {
            b.observe(false);
        }
        b.observe(true);
        assert_eq!(b.tick(1_000.0), 1, "80% failure steps up");
        for _ in 0..4 {
            b.observe(false);
        }
        assert_eq!(b.tick(2_000.0), 2);
        for _ in 0..4 {
            b.observe(false);
        }
        assert_eq!(b.tick(3_000.0), 2, "capped at max_level");
        assert_eq!(b.tick(4_000.0), 1, "empty window reads healthy");
        assert_eq!(b.tick(5_000.0), 0);
        assert!((b.degraded_ms(9_000.0) - 4_000.0).abs() < 1e-9, "1s..5s degraded");
    }

    #[test]
    fn brownout_open_interval_accrues_without_mutation() {
        let mut b = Brownout::new(BrownoutConfig {
            enter_failure_rate: 0.5,
            exit_failure_rate: 0.1,
            max_level: 3,
        });
        b.observe(false);
        assert_eq!(b.tick(100.0), 1);
        assert!((b.degraded_ms(250.0) - 150.0).abs() < 1e-9);
        assert!((b.degraded_ms(250.0) - 150.0).abs() < 1e-9, "pure read");
    }

    #[test]
    fn resilience_defaults_are_off_and_storm_is_on() {
        assert!(!ResilienceConfig::default().any_on());
        assert!(ResilienceConfig::storm_defaults().any_on());
    }
}
