//! Cluster-scale serving fabric — the closed loop over placement,
//! serving, and measurement.
//!
//! The paper's premise is that TF2AIF emits *many* platform variants of
//! one AI function so the orchestrator can place it anywhere on the
//! cloud-edge continuum.  Before this module, the repo had the pieces but
//! not the loop: `cluster` simulated placement without live traffic,
//! `serving` drove a single `AifServer`, and `backend` ranked variants
//! from static cost models.  The fabric wires them into one system:
//!
//! ```text
//!             ┌────────────────────────── Fabric ─────────────────────────┐
//!  requests   │  Router ──► per-pod BoundedQueue ──► batcher workers ──►  │
//!  (Arrival)──┤   │  │          (admission bound,     ONE fused dispatch  │
//!             │   │  │shed       shed when full)      per drained batch   │
//!             │   │  ▼                                (AifServer|SimPod)  │
//!             │   │ dedup: identical in-flight            │               │
//!             │   │ requests collapse into one            │               │
//!             │   │ execution, responses fan out          │               │
//!             │   ▼                                       │               │
//!             │  FeedbackStore ◄─── observed service latency              │
//!             │     │                                                     │
//!             │     └──► backend::Backend::rank (placement re-scoring)    │
//!             └───────────────────────────────────────────────────────────┘
//! ```
//!
//! - **Sharding** — every AIF gets up to `replicas_per_model` pods bound
//!   on distinct cluster nodes (scheduler filter + bind per
//!   [`crate::cluster::Cluster`]); the router spreads requests across
//!   them by least estimated work.
//! - **Per-node queues & fused dynamic batching** — each pod owns a
//!   [`queue::BoundedQueue`] drained in batches by its own workers, so a
//!   slow far-edge pod queues independently of a fast cloud GPU pod; the
//!   drained batch then executes as ONE device dispatch
//!   ([`PodExecutor::execute_batch`]), amortizing per-dispatch overhead
//!   over the batch (`tf2aif bench` measures the curve).
//! - **Request dedup / response memoization** — identical concurrent
//!   (model, payload) submissions collapse into one execution keyed by
//!   input hash; every caller gets a response re-stamped with its own
//!   request id.
//! - **Admission control** — queues are bounded; when every replica's
//!   queue is full the request is *shed* explicitly (counted, never
//!   silently dropped).
//! - **Feedback** — completed requests update a
//!   [`crate::metrics::FeedbackStore`]; the router and
//!   [`crate::backend::Backend::rank`] blend those measurements into
//!   their scores, so routing and placement adapt to delivered
//!   performance.
//!
//! See `docs/ARCHITECTURE.md` for the full request lifecycle and
//! `examples/fabric_poisson.rs` or `tf2aif fabric` for runnable drivers.

pub mod bench;
pub mod queue;
pub mod sim;

use std::collections::{BTreeMap, BTreeSet, HashMap};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;
use std::time::Instant;

use anyhow::{bail, Context as _, Result};
use sha2::{Digest as _, Sha256};

use crate::artifact::Artifact;
use crate::backend::Backend;
use crate::cluster::Cluster;
use crate::metrics::{Collector, FeedbackStore, Snapshot};
use crate::runtime::Engine;
use crate::serving::{AifServer, ImageClassify, Request, Response};
use crate::util::rng::Rng;
use crate::util::stats::{throughput_rps, Boxplot, Series};
use crate::workload::{image_like, Arrival};

use queue::BoundedQueue;
use sim::{Gate, SimPod};

/// Anything that can serve fabric requests: a real PJRT-backed
/// [`AifServer`] or a [`SimPod`] running the platform cost model.
pub trait PodExecutor: Send + Sync {
    /// Serve one request that waited `queue_wait_ms` in the pod queue.
    fn execute(&self, req: &Request, queue_wait_ms: f64) -> Result<Response>;
    /// Serve a whole drained batch as ONE fused dispatch (per-item
    /// results in request order — a malformed item fails alone).
    fn execute_batch(&self, reqs: &[Request], queue_wait_ms: &[f64]) -> Vec<Result<Response>>;
    /// The pod's metrics collector.
    fn collector(&self) -> &Arc<Collector>;
}

impl PodExecutor for AifServer {
    fn execute(&self, req: &Request, queue_wait_ms: f64) -> Result<Response> {
        self.handle_queued(req, queue_wait_ms)
    }

    fn execute_batch(&self, reqs: &[Request], queue_wait_ms: &[f64]) -> Vec<Result<Response>> {
        self.handle_batch(reqs, queue_wait_ms)
    }

    fn collector(&self) -> &Arc<Collector> {
        &self.metrics
    }
}

impl PodExecutor for SimPod {
    fn execute(&self, req: &Request, queue_wait_ms: f64) -> Result<Response> {
        SimPod::execute(self, req, queue_wait_ms)
    }

    fn execute_batch(&self, reqs: &[Request], queue_wait_ms: &[f64]) -> Vec<Result<Response>> {
        SimPod::execute_batch(self, reqs, queue_wait_ms)
    }

    fn collector(&self) -> &Arc<Collector> {
        self.metrics()
    }
}

/// Fabric tuning knobs.
#[derive(Debug, Clone)]
pub struct FabricConfig {
    /// Admission bound: queued requests per pod before shedding.
    pub queue_capacity: usize,
    /// Max requests one worker drains per wakeup (dynamic batch size).
    pub max_batch: usize,
    /// Batcher workers per pod.
    pub workers: usize,
    /// Max pods (on distinct nodes) per AIF.
    pub replicas_per_model: usize,
    /// EWMA smoothing for the feedback store.
    pub feedback_alpha: f64,
    /// Simulated pods: fraction of modeled latency really slept.
    pub time_scale: f64,
    /// Seed for simulated-pod noise.
    pub seed: u64,
    /// Fused batch execution: a drained batch becomes ONE device
    /// dispatch.  `false` restores the per-item reference path (each
    /// drained request dispatched individually) — the baseline the
    /// `tf2aif bench` sweep measures fusion against.
    pub fused: bool,
    /// In-flight request dedup: identical concurrent (model, payload)
    /// submissions collapse into one execution whose response is fanned
    /// back out to every caller (memoized while in flight).
    pub dedup: bool,
}

impl Default for FabricConfig {
    fn default() -> Self {
        FabricConfig {
            queue_capacity: 16,
            max_batch: 8,
            workers: 1,
            replicas_per_model: 3,
            feedback_alpha: 0.2,
            time_scale: 0.05,
            seed: 0xFAB,
            fused: true,
            dedup: true,
        }
    }
}

/// One placed pod: the fabric's record of a scheduler bind.
#[derive(Debug, Clone)]
pub struct PodPlan {
    /// AIF identity (`model_variant`).
    pub aif: String,
    /// Model served.
    pub model: String,
    /// Platform variant served.
    pub variant: String,
    /// Cluster node hosting the pod.
    pub node: String,
    /// Pod id from the cluster bind.
    pub pod_id: u64,
    /// Cost-model service latency used at placement time, ms.
    pub modeled_ms: f64,
}

type Work = (Request, Instant, Arc<Fanout>);

/// Terminal state of one routed request.
#[derive(Debug, Clone)]
pub enum Outcome {
    /// Served; full latency breakdown inside.
    Completed(Response),
    /// Reached a pod but the executor failed (counted in pod errors).
    Failed(String),
}

/// Delivery record for one admitted (leader) request: the waiters are
/// every caller whose submission collapsed onto this execution — the
/// leader itself plus any dedup'd followers that attached while it was in
/// flight.
struct Fanout {
    /// Dedup-map key to unregister on completion (`None` when dedup is
    /// off for this submission).
    key: Option<[u8; 32]>,
    waiters: Mutex<Vec<(u64, mpsc::Sender<Outcome>)>>,
}

/// In-flight dedup index: content hash → the execution to piggyback on.
type DedupMap = Mutex<HashMap<[u8; 32], Arc<Fanout>>>;

/// Content hash of a routed request — the dedup/memoization key.  The
/// model name is part of the digest so identical tensors aimed at
/// different AIFs never collapse.
fn dedup_key(model: &str, payload: &[f32]) -> [u8; 32] {
    let mut h = Sha256::new();
    h.update(model.as_bytes());
    h.update([0u8]);
    // Stream fixed-size chunks through a stack buffer: no payload-sized
    // allocation on the admission path.
    let mut buf = [0u8; 4096];
    for chunk in payload.chunks(buf.len() / 4) {
        let mut n = 0;
        for v in chunk {
            buf[n..n + 4].copy_from_slice(&v.to_le_bytes());
            n += 4;
        }
        h.update(&buf[..n]);
    }
    *h.finalize().as_bytes()
}

/// Unregister a completed execution from the dedup index, then fan its
/// outcome out to every waiter (each response re-stamped with the
/// waiter's own request id).  Removal happens under the map lock *before*
/// delivery, so a new identical submission either attached in time (and
/// is in `waiters`) or starts a fresh execution — nobody can attach to a
/// completed entry and hang.
fn deliver(dedup: &DedupMap, fan: &Fanout, outcome: Outcome) {
    if let Some(key) = &fan.key {
        dedup.lock().unwrap().remove(key);
    }
    let waiters = std::mem::take(&mut *fan.waiters.lock().unwrap());
    for (id, tx) in waiters {
        let personalized = match &outcome {
            Outcome::Completed(resp) => Outcome::Completed(Response { id, ..resp.clone() }),
            Outcome::Failed(e) => Outcome::Failed(e.clone()),
        };
        let _ = tx.send(personalized);
    }
}

/// Router verdict for one submission.
pub enum Submission {
    /// Admitted to a pod queue; the receiver yields the [`Outcome`].
    Enqueued(mpsc::Receiver<Outcome>),
    /// Every feasible replica's queue was at the admission bound; the
    /// request was shed (and counted).
    Shed,
}

struct PodRuntime {
    plan: PodPlan,
    key: String,
    queue: Arc<BoundedQueue<Work>>,
    /// Queued + executing requests (router backlog estimate).
    backlog: Arc<AtomicU64>,
    executor: Arc<dyn PodExecutor>,
    workers: Vec<thread::JoinHandle<()>>,
}

/// The serving fabric: every placed pod plus the router state.
pub struct Fabric {
    pods: Vec<PodRuntime>,
    by_model: BTreeMap<String, Vec<usize>>,
    input_shapes: BTreeMap<String, (usize, usize, usize)>,
    feedback: Arc<FeedbackStore>,
    cfg: FabricConfig,
    next_id: AtomicU64,
    shed_total: AtomicU64,
    shed_by_model: Mutex<BTreeMap<String, u64>>,
    /// In-flight dedup index, shared with every pod worker.
    dedup: Arc<DedupMap>,
    dedup_hits: AtomicU64,
}

/// Plan replica placements for every model the backend knows, binding
/// pods through the cluster scheduler (filter → score → bind).  Ranking
/// is refreshed per model so later models see earlier binds' slot and
/// memory consumption; a rank entry whose capacity raced away simply
/// fails its bind and the next candidate is tried.
fn plan_placements(
    backend: &Backend,
    cluster: &mut Cluster,
    replicas: usize,
) -> Result<Vec<(PodPlan, Arc<Artifact>)>> {
    let models: Vec<String> = backend.models().iter().map(|m| m.to_string()).collect();
    if models.is_empty() {
        bail!("backend has no models to place");
    }
    let mut out = Vec::new();
    for model in &models {
        let mut nodes_used: BTreeSet<String> = BTreeSet::new();
        let ranked = backend.rank(model, cluster)?;
        for d in ranked {
            if nodes_used.len() >= replicas.max(1) {
                break;
            }
            if nodes_used.contains(&d.node) {
                continue;
            }
            // One clone at placement time, shared (`Arc`) with the pod
            // executor and the runtime host from here on.
            let artifact = Arc::new(
                backend
                    .variants_of(model)
                    .into_iter()
                    .find(|a| a.manifest.variant == d.variant)
                    .context("ranked variant missing from index")?
                    .clone(),
            );
            let mem = Backend::pod_memory_gb(&artifact);
            let Ok(pod_id) = cluster.bind(&d.aif, &d.variant, &d.node, mem) else {
                continue; // capacity raced away since ranking
            };
            nodes_used.insert(d.node.clone());
            out.push((
                PodPlan {
                    aif: d.aif.clone(),
                    model: model.clone(),
                    variant: d.variant.clone(),
                    node: d.node.clone(),
                    pod_id,
                    modeled_ms: d.modeled_ms,
                },
                artifact,
            ));
        }
        if nodes_used.is_empty() {
            bail!("no feasible placement for model {model:?}");
        }
    }
    Ok(out)
}

impl Fabric {
    /// Place and spawn the fabric with **simulated** pods (platform cost
    /// models; no artifacts or PJRT needed).  `gate`, when provided, is
    /// installed in every pod for deterministic overload tests.
    pub fn place_sim(
        backend: &Backend,
        cluster: &mut Cluster,
        cfg: &FabricConfig,
        gate: Option<Arc<Gate>>,
    ) -> Result<Fabric> {
        let plans = plan_placements(backend, cluster, cfg.replicas_per_model)?;
        let mut pods: Vec<(PodPlan, Arc<Artifact>, Arc<dyn PodExecutor>)> = Vec::new();
        for (plan, artifact) in plans {
            let pod = SimPod::new(
                &plan.variant,
                artifact.manifest.gflops,
                cfg.time_scale,
                cfg.seed ^ plan.pod_id,
                gate.clone(),
            )?;
            pods.push((plan, artifact, Arc::new(pod)));
        }
        Ok(Fabric::spawn(pods, cfg.clone()))
    }

    /// Place and spawn the fabric with **real** pods: one compiled,
    /// weight-pinned [`AifServer`] per placement (requires on-disk
    /// artifacts).
    pub fn place_real(
        backend: &Backend,
        cluster: &mut Cluster,
        engine: &Engine,
        cfg: &FabricConfig,
    ) -> Result<Fabric> {
        let plans = plan_placements(backend, cluster, cfg.replicas_per_model)?;
        let mut pods: Vec<(PodPlan, Arc<Artifact>, Arc<dyn PodExecutor>)> = Vec::new();
        for (plan, artifact) in plans {
            let server = AifServer::deploy(engine, &artifact, Arc::new(ImageClassify))?;
            pods.push((plan, artifact, Arc::new(server)));
        }
        Ok(Fabric::spawn(pods, cfg.clone()))
    }

    fn spawn(
        pods: Vec<(PodPlan, Arc<Artifact>, Arc<dyn PodExecutor>)>,
        cfg: FabricConfig,
    ) -> Fabric {
        let feedback = Arc::new(FeedbackStore::new(cfg.feedback_alpha));
        let dedup: Arc<DedupMap> = Arc::new(Mutex::new(HashMap::new()));
        let mut runtimes = Vec::new();
        let mut by_model: BTreeMap<String, Vec<usize>> = BTreeMap::new();
        let mut input_shapes = BTreeMap::new();
        for (idx, (plan, artifact, executor)) in pods.into_iter().enumerate() {
            let s = &artifact.manifest.input_shape;
            if s.len() == 4 {
                input_shapes.entry(plan.model.clone()).or_insert((s[1], s[2], s[3]));
            }
            let queue = Arc::new(BoundedQueue::new(cfg.queue_capacity));
            let backlog = Arc::new(AtomicU64::new(0));
            let key = FeedbackStore::key(&plan.aif, &plan.node);
            let workers = (0..cfg.workers.max(1))
                .map(|_| {
                    let queue = Arc::clone(&queue);
                    let backlog = Arc::clone(&backlog);
                    let executor = Arc::clone(&executor);
                    let feedback = Arc::clone(&feedback);
                    let dedup = Arc::clone(&dedup);
                    let key = key.clone();
                    let max_batch = cfg.max_batch.max(1);
                    let fused = cfg.fused;
                    thread::spawn(move || {
                        let finish = |fan: Arc<Fanout>, result: Result<Response>| {
                            let outcome = match result {
                                Ok(resp) => {
                                    feedback.observe(&key, resp.service_ms);
                                    Outcome::Completed(resp)
                                }
                                Err(e) => Outcome::Failed(format!("{e:#}")),
                            };
                            backlog.fetch_sub(1, Ordering::Relaxed);
                            deliver(&dedup, &fan, outcome);
                        };
                        loop {
                            // `None` = closed and drained: the
                            // unambiguous shutdown signal (workers
                            // block, never spin).
                            let Some(batch) = queue.pop_batch(max_batch) else {
                                break;
                            };
                            if fused {
                                // The whole drained batch is ONE device
                                // dispatch; every item stops waiting at
                                // dispatch time.
                                let mut reqs = Vec::with_capacity(batch.len());
                                let mut waits = Vec::with_capacity(batch.len());
                                let mut fans = Vec::with_capacity(batch.len());
                                for (req, enqueued, fan) in batch {
                                    waits.push(enqueued.elapsed().as_secs_f64() * 1e3);
                                    reqs.push(req);
                                    fans.push(fan);
                                }
                                let results = executor.execute_batch(&reqs, &waits);
                                for (fan, result) in fans.into_iter().zip(results) {
                                    finish(fan, result);
                                }
                            } else {
                                // Per-item reference path (the bench
                                // baseline): one dispatch per request,
                                // and each item's queue wait is taken at
                                // its OWN execution time so the in-batch
                                // serial wait is attributed honestly.
                                for (req, enqueued, fan) in batch {
                                    let wait_ms = enqueued.elapsed().as_secs_f64() * 1e3;
                                    let result = executor.execute(&req, wait_ms);
                                    finish(fan, result);
                                }
                            }
                        }
                    })
                })
                .collect();
            by_model.entry(plan.model.clone()).or_default().push(idx);
            runtimes.push(PodRuntime { plan, key, queue, backlog, executor, workers });
        }
        Fabric {
            pods: runtimes,
            by_model,
            input_shapes,
            feedback,
            cfg,
            next_id: AtomicU64::new(0),
            shed_total: AtomicU64::new(0),
            shed_by_model: Mutex::new(BTreeMap::new()),
            dedup,
            dedup_hits: AtomicU64::new(0),
        }
    }

    /// The shared feedback store (attach it to a
    /// [`Backend`](crate::backend::Backend) via its `feedback` field so
    /// future placements see fabric measurements).
    pub fn feedback(&self) -> Arc<FeedbackStore> {
        Arc::clone(&self.feedback)
    }

    /// The configuration the fabric was spawned with.
    pub fn config(&self) -> &FabricConfig {
        &self.cfg
    }

    /// Placed pods, in placement order.
    pub fn plans(&self) -> Vec<PodPlan> {
        self.pods.iter().map(|p| p.plan.clone()).collect()
    }

    /// Distinct cluster nodes hosting at least one pod.
    pub fn nodes_spanned(&self) -> BTreeSet<String> {
        self.pods.iter().map(|p| p.plan.node.clone()).collect()
    }

    /// Models the fabric can route.
    pub fn models(&self) -> Vec<String> {
        self.by_model.keys().cloned().collect()
    }

    /// NHWC input shape for a model's requests, from its placed artifact.
    pub fn input_shape(&self, model: &str) -> Option<(usize, usize, usize)> {
        self.input_shapes.get(model).copied()
    }

    /// Router score for a pod: estimated per-request latency (feedback
    /// blended over the cost model) scaled by its backlog — a
    /// least-estimated-work-left policy.
    fn score(&self, idx: usize) -> f64 {
        let pod = &self.pods[idx];
        let est = self.feedback.blend(&pod.key, pod.plan.modeled_ms);
        let backlog = pod.backlog.load(Ordering::Relaxed) as f64;
        est * (backlog + 1.0)
    }

    /// Route one request for `model`: collapse onto an identical
    /// in-flight request when dedup is on, otherwise try the replicas in
    /// ascending score order, admit into the first queue with room, and
    /// shed if every queue is at the bound.  Shed requests are counted —
    /// nothing is silently dropped.
    pub fn submit(&self, model: &str, payload: Vec<f32>) -> Result<Submission> {
        let Some(replicas) = self.by_model.get(model) else {
            bail!("fabric serves no model {model:?} (have: {:?})", self.models());
        };
        let mut scored: Vec<(f64, usize)> =
            replicas.iter().map(|&i| (self.score(i), i)).collect();
        scored.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());

        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = mpsc::channel();

        if self.cfg.dedup {
            let key = dedup_key(model, &payload);
            // The map lock is held across attach/route/register so a
            // completing worker (which also takes it, in `deliver`)
            // cannot unregister an entry between our lookup and our
            // attach — a waiter either rides the in-flight execution or
            // becomes a fresh leader, never neither.  The critical
            // section is small: replica scoring already happened above,
            // so under the lock we only do backlog atomics and at most
            // `replicas` O(1) queue pushes.  (Registering before routing
            // would shrink it further but forces shed-time notification
            // of any followers that attached in the window — a worse
            // semantics trade.)
            let mut map = self.dedup.lock().unwrap();
            if let Some(entry) = map.get(&key) {
                entry.waiters.lock().unwrap().push((id, tx));
                self.dedup_hits.fetch_add(1, Ordering::Relaxed);
                return Ok(Submission::Enqueued(rx));
            }
            let fan =
                Arc::new(Fanout { key: Some(key), waiters: Mutex::new(vec![(id, tx)]) });
            let work: Work = (Request { id, payload }, Instant::now(), Arc::clone(&fan));
            if self.try_route(&scored, work) {
                map.insert(key, fan);
                return Ok(Submission::Enqueued(rx));
            }
        } else {
            let fan = Arc::new(Fanout { key: None, waiters: Mutex::new(vec![(id, tx)]) });
            let work: Work = (Request { id, payload }, Instant::now(), fan);
            if self.try_route(&scored, work) {
                return Ok(Submission::Enqueued(rx));
            }
        }
        self.shed_total.fetch_add(1, Ordering::Relaxed);
        *self.shed_by_model.lock().unwrap().entry(model.to_string()).or_insert(0) += 1;
        Ok(Submission::Shed)
    }

    /// Try each scored replica in order; `true` when a queue admitted the
    /// work, `false` when every queue was at the admission bound.
    fn try_route(&self, scored: &[(f64, usize)], mut work: Work) -> bool {
        for &(_, idx) in scored {
            let pod = &self.pods[idx];
            pod.backlog.fetch_add(1, Ordering::Relaxed);
            match pod.queue.try_push(work) {
                Ok(()) => return true,
                Err(returned) => {
                    pod.backlog.fetch_sub(1, Ordering::Relaxed);
                    work = returned;
                }
            }
        }
        false
    }

    /// Total shed requests so far.
    pub fn shed_total(&self) -> u64 {
        self.shed_total.load(Ordering::Relaxed)
    }

    /// Submissions that collapsed onto an identical in-flight request
    /// (served by memoized fan-out instead of a fresh execution).
    pub fn dedup_hits(&self) -> u64 {
        self.dedup_hits.load(Ordering::Relaxed)
    }

    /// Shed counts per model.
    pub fn shed_by_model(&self) -> BTreeMap<String, u64> {
        self.shed_by_model.lock().unwrap().clone()
    }

    /// Drive a workload through the router: `requests` synthetic
    /// image-classification requests spread round-robin over `models`
    /// (all placed models when empty), paced by `arrival`.
    ///
    /// `Arrival::ClosedLoop` keeps exactly one request outstanding (the
    /// paper's benchmark semantics, matching the single-AIF
    /// [`Client`](crate::client::Client) driver — shedding cannot occur).
    /// Open-loop arrivals submit asynchronously; real sleep per gap is
    /// capped at 2 ms, mirroring the client driver.
    pub fn run(&self, requests: usize, arrival: Arrival, seed: u64) -> Result<FabricRunReport> {
        self.run_with(requests, arrival, seed, |rng: &mut Rng, model: &str, _i: usize| {
            let (h, w, c) = self.input_shape(model).unwrap_or((8, 8, 1));
            image_like(rng, h, w, c)
        })
    }

    /// [`run`](Self::run) with a caller-supplied payload source — the
    /// single drive loop shared by `tf2aif fabric` (fresh image-like
    /// payloads) and the `tf2aif bench` sweep (pre-generated payload
    /// pool), so pacing and accounting can never diverge between them.
    /// `payload_for` receives the workload RNG, the target model and the
    /// request index.
    pub fn run_with(
        &self,
        requests: usize,
        arrival: Arrival,
        seed: u64,
        mut payload_for: impl FnMut(&mut Rng, &str, usize) -> Vec<f32>,
    ) -> Result<FabricRunReport> {
        let models = self.models();
        if models.is_empty() {
            bail!("fabric has no pods");
        }
        let closed_loop = arrival == Arrival::ClosedLoop;
        let mut rng = Rng::new(seed);
        let t0 = Instant::now();
        let mut pending = Vec::new();
        let mut shed = 0usize;
        let mut completed = 0usize;
        let mut failed = 0usize;
        let mut e2e_ms = Series::new();
        fn account(
            outcome: Option<Outcome>,
            completed: &mut usize,
            failed: &mut usize,
            e2e_ms: &mut Series,
        ) {
            match outcome {
                Some(Outcome::Completed(resp)) => {
                    *completed += 1;
                    e2e_ms.push(resp.queue_wait_ms + resp.service_ms);
                }
                Some(Outcome::Failed(_)) | None => *failed += 1,
            }
        }
        for i in 0..requests {
            if let Some(gap) = arrival.next_gap_s(&mut rng) {
                std::thread::sleep(std::time::Duration::from_secs_f64(gap.min(0.002)));
            }
            let model = &models[i % models.len()];
            let payload = payload_for(&mut rng, model, i);
            match self.submit(model, payload)? {
                Submission::Enqueued(rx) => {
                    if closed_loop {
                        // One outstanding request: wait before issuing
                        // the next (paper §V-C closed loop).
                        account(rx.recv().ok(), &mut completed, &mut failed, &mut e2e_ms);
                    } else {
                        pending.push(rx);
                    }
                }
                Submission::Shed => shed += 1,
            }
        }
        for rx in pending {
            account(rx.recv().ok(), &mut completed, &mut failed, &mut e2e_ms);
        }
        Ok(FabricRunReport {
            submitted: requests,
            completed,
            shed,
            failed,
            e2e_ms,
            wall_s: t0.elapsed().as_secs_f64(),
        })
    }

    /// Per-pod report rows (snapshot of each pod's collector).
    pub fn pod_reports(&self, wall_s: f64) -> Vec<PodReport> {
        self.pods
            .iter()
            .map(|p| {
                let snap = p.executor.collector().snapshot();
                PodReport::from_snapshot(&p.plan, snap, wall_s)
            })
            .collect()
    }

    /// Fleet-aggregate report (merged pod snapshots + shed counters).
    pub fn fleet_report(&self, wall_s: f64) -> FleetReport {
        let snaps: Vec<Snapshot> =
            self.pods.iter().map(|p| p.executor.collector().snapshot()).collect();
        let merged = Snapshot::merged(snaps);
        FleetReport {
            pods: self.pods.len(),
            nodes: self.nodes_spanned().len(),
            requests: merged.requests,
            errors: merged.errors,
            shed: self.shed_total(),
            deduped: self.dedup_hits(),
            service: boxplot_opt(&merged.service_ms),
            mean_queue_wait_ms: mean_opt(&merged.queue_wait_ms),
            throughput_rps: throughput_rps(merged.requests as usize, wall_s),
        }
    }

    /// Close every pod queue, drain backlogs, join workers.
    pub fn shutdown(self) {
        for p in &self.pods {
            p.queue.close();
        }
        for p in self.pods {
            for w in p.workers {
                let _ = w.join();
            }
        }
    }
}

fn boxplot_opt(s: &Series) -> Option<Boxplot> {
    if s.is_empty() {
        None
    } else {
        Some(s.clone().boxplot())
    }
}

fn mean_opt(s: &Series) -> f64 {
    if s.is_empty() {
        0.0
    } else {
        s.mean()
    }
}

/// Result of one [`Fabric::run`] drive.
#[derive(Debug, Clone)]
pub struct FabricRunReport {
    /// Requests submitted to the router.
    pub submitted: usize,
    /// Requests served to completion.
    pub completed: usize,
    /// Requests shed at the admission bound.
    pub shed: usize,
    /// Requests that reached a pod but failed there.
    pub failed: usize,
    /// End-to-end (queue wait + service) latencies of completed
    /// requests, ms.
    pub e2e_ms: Series,
    /// Wall-clock of the whole drive, seconds.
    pub wall_s: f64,
}

impl FabricRunReport {
    /// Completed-request throughput over the drive wall-clock.
    pub fn throughput_rps(&self) -> f64 {
        throughput_rps(self.completed, self.wall_s)
    }

    /// Every submitted request must be accounted: completed, failed, or
    /// explicitly shed.
    pub fn fully_accounted(&self) -> bool {
        self.completed + self.failed + self.shed == self.submitted
    }
}

/// One pod's row in the fabric report.
#[derive(Debug, Clone)]
pub struct PodReport {
    /// AIF identity (`model_variant`).
    pub aif: String,
    /// Platform variant.
    pub variant: String,
    /// Hosting node.
    pub node: String,
    /// Requests served.
    pub requests: u64,
    /// Executor errors.
    pub errors: u64,
    /// Service-latency five-number summary (None when idle).
    pub service: Option<Boxplot>,
    /// Mean time requests spent queued, ms.
    pub mean_queue_wait_ms: f64,
    /// Served throughput over the drive wall-clock.
    pub throughput_rps: f64,
}

impl PodReport {
    fn from_snapshot(plan: &PodPlan, snap: Snapshot, wall_s: f64) -> PodReport {
        PodReport {
            aif: plan.aif.clone(),
            variant: plan.variant.clone(),
            node: plan.node.clone(),
            requests: snap.requests,
            errors: snap.errors,
            service: boxplot_opt(&snap.service_ms),
            mean_queue_wait_ms: mean_opt(&snap.queue_wait_ms),
            throughput_rps: throughput_rps(snap.requests as usize, wall_s),
        }
    }
}

/// Fleet-aggregate row in the fabric report.
#[derive(Debug, Clone)]
pub struct FleetReport {
    /// Placed pods.
    pub pods: usize,
    /// Distinct nodes hosting pods.
    pub nodes: usize,
    /// Requests served fleet-wide.
    pub requests: u64,
    /// Executor errors fleet-wide.
    pub errors: u64,
    /// Requests shed at admission.
    pub shed: u64,
    /// Submissions answered by in-flight dedup (no fresh execution).
    pub deduped: u64,
    /// Merged service-latency summary (None when idle).
    pub service: Option<Boxplot>,
    /// Mean queue wait fleet-wide, ms.
    pub mean_queue_wait_ms: f64,
    /// Fleet throughput over the drive wall-clock.
    pub throughput_rps: f64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::Policy;
    use crate::cluster::paper_testbed;

    fn sim_fabric(cfg: &FabricConfig, gate: Option<Arc<Gate>>) -> Fabric {
        let backend = Backend::new(sim::synthetic_catalog(), Policy::MinLatency);
        let mut cluster = Cluster::new(paper_testbed());
        cluster.apply_kube_api_extension();
        Fabric::place_sim(&backend, &mut cluster, cfg, gate).unwrap()
    }

    #[test]
    fn placement_shards_models_across_nodes() {
        let cfg = FabricConfig { time_scale: 0.0, ..Default::default() };
        let fabric = sim_fabric(&cfg, None);
        assert_eq!(fabric.models().len(), 4, "all Table III models placed");
        assert!(
            fabric.nodes_spanned().len() >= 3,
            "fleet must span the Table II testbed, got {:?}",
            fabric.nodes_spanned()
        );
        for model in fabric.models() {
            let nodes: BTreeSet<_> = fabric
                .plans()
                .into_iter()
                .filter(|p| p.model == model)
                .map(|p| p.node)
                .collect();
            assert!(!nodes.is_empty(), "{model} unplaced");
            assert!(nodes.len() <= cfg.replicas_per_model);
        }
        fabric.shutdown();
    }

    #[test]
    fn replicas_land_on_distinct_nodes() {
        let cfg = FabricConfig { time_scale: 0.0, ..Default::default() };
        let fabric = sim_fabric(&cfg, None);
        for model in fabric.models() {
            let nodes: Vec<_> = fabric
                .plans()
                .into_iter()
                .filter(|p| p.model == model)
                .map(|p| p.node)
                .collect();
            let distinct: BTreeSet<_> = nodes.iter().cloned().collect();
            assert_eq!(nodes.len(), distinct.len(), "{model}: replica nodes must differ");
        }
        fabric.shutdown();
    }

    #[test]
    fn closed_loop_run_completes_everything() {
        let cfg = FabricConfig { time_scale: 0.0, ..Default::default() };
        let fabric = sim_fabric(&cfg, None);
        let report = fabric.run(40, Arrival::ClosedLoop, 11).unwrap();
        assert!(report.fully_accounted());
        assert_eq!(report.failed, 0);
        assert_eq!(report.completed + report.shed, 40);
        assert!(report.completed > 0);
        let fleet = fabric.fleet_report(report.wall_s);
        assert_eq!(fleet.requests, report.completed as u64);
        assert_eq!(fleet.shed as usize, report.shed);
        fabric.shutdown();
    }

    #[test]
    fn feedback_store_learns_from_traffic() {
        let cfg = FabricConfig { time_scale: 0.0, ..Default::default() };
        let fabric = sim_fabric(&cfg, None);
        fabric.run(60, Arrival::ClosedLoop, 3).unwrap();
        let store = fabric.feedback();
        assert!(
            !store.all().is_empty(),
            "completed traffic must produce feedback observations"
        );
        for (key, fb) in store.all() {
            assert!(fb.ewma_service_ms > 0.0, "{key}");
            assert!(fb.observations > 0);
        }
        fabric.shutdown();
    }

    #[test]
    fn unknown_model_is_an_error_not_a_silent_drop() {
        let cfg = FabricConfig { time_scale: 0.0, ..Default::default() };
        let fabric = sim_fabric(&cfg, None);
        assert!(fabric.submit("not-a-model", vec![]).is_err());
        fabric.shutdown();
    }

    #[test]
    fn dedup_entry_is_removed_after_completion() {
        // Without a gate the execution completes quickly; afterwards the
        // same payload must start a fresh execution (memoization is
        // in-flight only, never stale).
        let cfg = FabricConfig { time_scale: 0.0, ..Default::default() };
        let fabric = sim_fabric(&cfg, None);
        for round in 0..3 {
            match fabric.submit("lenet", vec![1.0; 32]).unwrap() {
                Submission::Enqueued(rx) => {
                    assert!(matches!(rx.recv().unwrap(), Outcome::Completed(_)), "{round}");
                }
                Submission::Shed => panic!("no load — must admit"),
            }
        }
        // Sequential identical submissions never overlapped → no hits,
        // three real executions.
        assert_eq!(fabric.dedup_hits(), 0);
        let served: u64 = fabric.pod_reports(1.0).iter().map(|r| r.requests).sum();
        assert_eq!(served, 3);
        fabric.shutdown();
    }

    #[test]
    fn dedup_key_separates_models_and_payloads() {
        let a = dedup_key("lenet", &[1.0, 2.0]);
        assert_eq!(a, dedup_key("lenet", &[1.0, 2.0]), "deterministic");
        assert_ne!(a, dedup_key("resnet50", &[1.0, 2.0]), "model is part of the key");
        assert_ne!(a, dedup_key("lenet", &[1.0, 2.5]), "payload is part of the key");
        assert_ne!(a, dedup_key("lenet", &[1.0]), "length is part of the key");
    }
}
