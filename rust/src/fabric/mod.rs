//! Cluster-scale serving fabric — the closed loop over placement,
//! serving, and measurement.
//!
//! The paper's premise is that TF2AIF emits *many* platform variants of
//! one AI function so the orchestrator can place it anywhere on the
//! cloud-edge continuum.  Before this module, the repo had the pieces but
//! not the loop: `cluster` simulated placement without live traffic,
//! `serving` drove a single `AifServer`, and `backend` ranked variants
//! from static cost models.  The fabric wires them into one system:
//!
//! ```text
//!             ┌────────────────────────── Fabric ─────────────────────────┐
//!  requests   │  Router ──► per-pod TenantQueue ──► batcher workers ──►   │
//!  (Arrival)──┤   │  │          (admission bound,     ONE fused dispatch  │
//!             │   │  │shed       shed when full)      per drained batch   │
//!             │   │  ▼                                (AifServer|SimPod)  │
//!             │   │ cache: fresh identical response?      │               │
//!             │   │ dedup: identical in-flight request?   │               │
//!             │   ▼                                       │               │
//!             │  FeedbackStore ◄─── observed service + queue-wait        │
//!             │     │                                     │               │
//!             │     ├──► backend::Backend::rank (placement re-scoring)    │
//!             │     ├──► BatchController (adaptive drain size per pod)    │
//!             │     └──► autoscaler (spawn/retire replicas per model)     │
//!             └───────────────────────────────────────────────────────────┘
//! ```
//!
//! - **Sharding** — every AIF gets up to `replicas_per_model` pods bound
//!   on distinct cluster nodes (scheduler filter + bind per
//!   [`crate::cluster::Cluster`]); the router spreads requests across
//!   them by least estimated work.
//! - **Per-node queues & fused dynamic batching** — each pod owns a
//!   [`queue::TenantQueue`] drained in batches by its own workers; the
//!   drained batch then executes as ONE device dispatch
//!   ([`PodExecutor::execute_batch`]), amortizing per-dispatch overhead
//!   over the batch (`tf2aif bench` measures the curve).
//! - **Adaptive batch sizing** (`FabricConfig::adaptive`) — each pod's
//!   [`control::BatchController`] picks the drain size per cycle from
//!   observed queue depth and the EWMA service/queue-wait feedback,
//!   growing batches under backlog and shrinking them when the tail
//!   approaches `slo_p99_ms` — the knob tunes itself.  A batch
//!   dominated by a tenant carrying its own SLO
//!   (`TenantSpec::slo_p99_ms`, `--tenant-slo`) backs off against that
//!   tenant's target instead of the global one.
//! - **Backlog-driven autoscaling** (`FabricConfig::autoscale`) — a
//!   control loop spawns and retires pod replicas per model from
//!   sustained backlog and shed counters, with hysteresis, cooldown and
//!   per-platform replica ceilings, placing new pods through the same
//!   `backend` ranking (feedback-blended) the initial placement used.
//!   With `AutoscaleConfig::predictive` the per-model offered-arrival
//!   EWMA is folded in as a forecast (Little's law), so the fleet
//!   scales on demand it can *see coming* instead of waiting for the
//!   backlog to materialize — the reactive path stays as the fallback.
//! - **Response cache** (`FabricConfig::cache_capacity`) — a bounded,
//!   TTL'd `(model, payload) → response` store answers repeats of
//!   recently completed requests without touching a queue.  Keys are
//!   two-tier: a cheap FNV-1a 64-bit pre-hash indexes the store, with
//!   sha256 computed only to confirm an occupied slot (see §Hot path in
//!   `docs/ARCHITECTURE.md`).
//! - **Request dedup / response memoization** — identical concurrent
//!   (model, payload) submissions collapse into one execution keyed by
//!   the same two-tier input hash; every caller gets a response
//!   re-stamped with its own request id.
//! - **Lock-free hot path** — the pod registry is an immutable
//!   epoch-published snapshot ([`SnapCell`]): submits read the current
//!   snapshot without taking any fabric-wide lock, scale-up/retire
//!   publish copy-on-write replacements, and payloads travel as shared
//!   `Arc<[f32]>` so fan-out, retries and spillover move a refcount,
//!   never tensor bytes.
//! - **Multi-tenancy** (`FabricConfig::tenants`) — requests carry a
//!   tenant id ([`Fabric::submit_as`]) with a priority class; admission
//!   enforces **per-tenant token-bucket quotas** and per-tenant queue
//!   shares *before* global capacity checks, workers drain batches
//!   **weighted-fair** across tenants (one hot tenant cannot starve the
//!   rest), and under pressure the shed path **preempts queued work by
//!   ascending priority** instead of bouncing the newcomer.  See
//!   [`tenancy`] and `docs/ARCHITECTURE.md` §Tenancy & fairness.
//! - **Admission control** — queues are bounded; when every replica's
//!   queue is full (of equal-or-higher-priority work) the request is
//!   *shed* (counted, never silent).
//! - **Feedback** — completed requests update a
//!   [`crate::metrics::FeedbackStore`]; the router,
//!   [`crate::backend::Backend::rank`], the batch controllers and the
//!   autoscaler all blend those measurements into their decisions.
//!
//! See `docs/ARCHITECTURE.md` (§Control plane) for the loops and
//! `examples/fabric_poisson.rs` or `tf2aif fabric` for runnable drivers.

pub mod bench;
pub mod cache;
pub mod control;
pub mod des;
pub mod faults;
pub mod queue;
pub mod sim;
pub mod tenancy;

use std::cell::RefCell;
use std::collections::{BTreeMap, BTreeSet, HashMap};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex, OnceLock};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{bail, Context as _, Result};
use sha2::{Digest as _, Sha256};

use crate::artifact::Artifact;
use crate::backend::{Backend, Policy};
use crate::cluster::Cluster;
use crate::metrics::{Collector, FeedbackStore, Snapshot};
use crate::platform;
use crate::runtime::Engine;
use crate::serving::{AifServer, ImageClassify, Request, Response};
use crate::util::hash::Fnv1a;
use crate::util::rng::Rng;
use crate::util::stats::{throughput_rps, Boxplot, Series};
use crate::workload::{image_like, Arrival};

use cache::ResponseCache;
pub use cache::{CacheExport, CacheStats};
use control::{ArrivalRate, BatchControlConfig, BatchController, HysteresisGate};
pub use control::{AutoscaleConfig, ScaleDirection, ScaleEvent};
use faults::CircuitBreaker;
pub use faults::{
    BreakerConfig, BrownoutConfig, Fault, FaultPlan, HedgePolicy, ResilienceConfig, RetryPolicy,
};
use queue::{LaneConfig, Push, TenantQueue};
use sim::{Gate, NullPod, SimPod};
use tenancy::{TenantRegistry, TenantState};
pub use tenancy::{Priority, TenancyError, TenantReport, TenantSpec, DEFAULT_TENANT};

/// Anything that can serve fabric requests: a real PJRT-backed
/// [`AifServer`] or a [`SimPod`] running the platform cost model.
pub trait PodExecutor: Send + Sync {
    /// Serve one request that waited `queue_wait_ms` in the pod queue.
    fn execute(&self, req: &Request, queue_wait_ms: f64) -> Result<Response>;
    /// Serve a whole drained batch as ONE fused dispatch (per-item
    /// results in request order — a malformed item fails alone).
    fn execute_batch(&self, reqs: &[Request], queue_wait_ms: &[f64]) -> Vec<Result<Response>>;
    /// The pod's metrics collector.
    fn collector(&self) -> &Arc<Collector>;
    /// Device dispatches performed so far (the amortization
    /// denominator: `requests / dispatches` = average fused batch).
    fn dispatches(&self) -> u64;
}

impl PodExecutor for AifServer {
    fn execute(&self, req: &Request, queue_wait_ms: f64) -> Result<Response> {
        self.handle_queued(req, queue_wait_ms)
    }

    fn execute_batch(&self, reqs: &[Request], queue_wait_ms: &[f64]) -> Vec<Result<Response>> {
        self.handle_batch(reqs, queue_wait_ms)
    }

    fn collector(&self) -> &Arc<Collector> {
        &self.metrics
    }

    fn dispatches(&self) -> u64 {
        AifServer::dispatches(self)
    }
}

impl PodExecutor for SimPod {
    fn execute(&self, req: &Request, queue_wait_ms: f64) -> Result<Response> {
        SimPod::execute(self, req, queue_wait_ms)
    }

    fn execute_batch(&self, reqs: &[Request], queue_wait_ms: &[f64]) -> Vec<Result<Response>> {
        SimPod::execute_batch(self, reqs, queue_wait_ms)
    }

    fn collector(&self) -> &Arc<Collector> {
        self.metrics()
    }

    fn dispatches(&self) -> u64 {
        SimPod::dispatches(self)
    }
}

impl PodExecutor for NullPod {
    fn execute(&self, req: &Request, queue_wait_ms: f64) -> Result<Response> {
        NullPod::execute(self, req, queue_wait_ms)
    }

    fn execute_batch(&self, reqs: &[Request], queue_wait_ms: &[f64]) -> Vec<Result<Response>> {
        NullPod::execute_batch(self, reqs, queue_wait_ms)
    }

    fn collector(&self) -> &Arc<Collector> {
        self.metrics()
    }

    fn dispatches(&self) -> u64 {
        NullPod::dispatches(self)
    }
}

/// Fabric tuning knobs.
#[derive(Debug, Clone)]
pub struct FabricConfig {
    /// Admission bound: queued requests per pod before shedding.
    pub queue_capacity: usize,
    /// Max requests one worker drains per wakeup.  With `adaptive` off
    /// this IS the drain size; with it on, it is the controller's upper
    /// bound.
    pub max_batch: usize,
    /// Adaptive batch sizing: each pod's drain size is chosen per cycle
    /// by a [`control::BatchController`] from queue depth and latency
    /// feedback instead of being pinned at `max_batch`.
    pub adaptive: bool,
    /// Smallest drain size the adaptive controller may pick.
    pub min_batch: usize,
    /// Tail-latency objective for the adaptive controller, ms
    /// end-to-end; `<= 0` disables the SLO term.
    pub slo_p99_ms: f64,
    /// Batch coalescing: a worker facing a less-than-full queue waits
    /// up to this long for the batch to fill before dispatching.  `0`
    /// (default) drains whatever is present immediately.
    pub batch_linger_ms: f64,
    /// Batcher workers per pod.
    pub workers: usize,
    /// Max pods (on distinct nodes) per AIF at placement time.
    pub replicas_per_model: usize,
    /// EWMA smoothing for the feedback store.
    pub feedback_alpha: f64,
    /// Simulated pods: fraction of modeled latency really slept.
    pub time_scale: f64,
    /// Seed for simulated-pod noise.
    pub seed: u64,
    /// Fused batch execution: a drained batch becomes ONE device
    /// dispatch.  `false` restores the per-item reference path (each
    /// drained request dispatched individually) — the baseline the
    /// `tf2aif bench` sweep measures fusion against.
    pub fused: bool,
    /// In-flight request dedup: identical concurrent (model, payload)
    /// submissions collapse into one execution whose response is fanned
    /// back out to every caller (memoized while in flight).
    pub dedup: bool,
    /// Response cache capacity (entries); `0` disables the cache.
    /// When on, completed responses are memoized for `cache_ttl_ms` and
    /// identical later submissions are answered without execution.
    pub cache_capacity: usize,
    /// Response-cache entry lifetime, ms.
    pub cache_ttl_ms: u64,
    /// Backlog-driven autoscaling of replicas per model; `None` keeps
    /// the placed replica count fixed.
    pub autoscale: Option<AutoscaleConfig>,
    /// Tenant set: per-tenant weights, priorities, quotas and queue
    /// shares (see [`tenancy`]).  Empty = a single unlimited
    /// [`DEFAULT_TENANT`]; a `"default"` tenant is appended when the
    /// list does not define one, so anonymous [`Fabric::submit`]
    /// traffic always has a home.
    pub tenants: Vec<TenantSpec>,
    /// Failure-handling policy: bounded retry on executor failure,
    /// per-pod circuit breakers, and (on the virtual-time path)
    /// tail-latency hedging and brownout degradation.  All off by
    /// default — the resilient fabric is opt-in per run.
    pub resilience: ResilienceConfig,
    /// Test hook: mask ANDed onto the 64-bit pre-hash before it indexes
    /// the dedup map and response cache.  `!0` (the default) leaves the
    /// hash untouched; equivalence tests narrow it (e.g. to `0xF`) to
    /// force pre-hash collisions and prove the sha256 confirm tier
    /// preserves exact dedup/memoization semantics.
    #[doc(hidden)]
    pub prehash_mask: u64,
}

impl Default for FabricConfig {
    fn default() -> Self {
        FabricConfig {
            queue_capacity: 16,
            max_batch: 8,
            adaptive: false,
            min_batch: 1,
            slo_p99_ms: 50.0,
            batch_linger_ms: 0.0,
            workers: 1,
            replicas_per_model: 3,
            feedback_alpha: 0.2,
            time_scale: 0.05,
            seed: 0xFAB,
            fused: true,
            dedup: true,
            cache_capacity: 0,
            cache_ttl_ms: 250,
            autoscale: None,
            tenants: Vec::new(),
            resilience: ResilienceConfig::default(),
            prehash_mask: !0,
        }
    }
}

/// One placed pod: the fabric's record of a scheduler bind.
#[derive(Debug, Clone)]
pub struct PodPlan {
    /// AIF identity (`model_variant`).
    pub aif: String,
    /// Model served.
    pub model: String,
    /// Platform variant served.
    pub variant: String,
    /// Cluster node hosting the pod.
    pub node: String,
    /// Pod id from the cluster bind.
    pub pod_id: u64,
    /// Cost-model service latency used at placement time, ms.
    pub modeled_ms: f64,
}

/// One queued unit: the admitted request, its enqueue instant, its
/// fan-out, and the tenancy coordinates the pod queue drains and
/// preempts by.
struct Work {
    req: Request,
    enqueued: Instant,
    fan: Arc<Fanout>,
    /// Tenant lane index in every pod queue.
    lane: usize,
    /// Priority rank (the queue's eviction ordering key).
    prio: u8,
    /// Executor-failure retries already consumed (0 on first admission);
    /// the retry policy bounds this before re-routing.
    attempt: u32,
}

/// Terminal state of one routed request.
#[derive(Debug, Clone)]
pub enum Outcome {
    /// Served; full latency breakdown inside.
    Completed(Response),
    /// Reached a pod but the executor failed (counted in pod errors).
    Failed(String),
    /// Admitted, then evicted from its queue by higher-priority work
    /// before executing (counted per tenant as a preemption and in the
    /// fleet shed totals — explicit, never silent).
    Shed,
}

/// One caller awaiting an outcome: its request id, the tenant to
/// account the verdict to, and its reply channel.
type Waiter = (u64, Arc<TenantState>, mpsc::Sender<Outcome>);

/// Delivery record for one admitted (leader) request: the waiters are
/// every caller whose submission collapsed onto this execution — the
/// leader itself plus any dedup'd followers that attached while it was in
/// flight.
struct Fanout {
    /// Tier-1 pre-hash the execution is registered under in the dedup
    /// index and the response cache (`None` when both are off).
    key: Option<u64>,
    /// Tier-2 confirm digest (`sha256(model, payload)`), computed
    /// lazily: only a pre-hash collision (a follower landing on this
    /// bucket) or the first-sight cache insert on completion forces it.
    sha: OnceLock<[u8; 32]>,
    /// Model this execution serves — the response cache's invalidation
    /// namespace and the dedup purge handle on artifact redeploy.
    model: String,
    /// The admitted payload, retained as a refcount bump so collision
    /// confirms can hash it lazily (never a byte copy).
    payload: Arc<[f32]>,
    /// Cache generation of `model` observed at admission; the insert is
    /// dropped if [`Fabric::on_artifact_redeploy`] bumped it mid-flight.
    cache_gen: u64,
    waiters: Mutex<Vec<Waiter>>,
}

impl Fanout {
    /// The confirm digest, computed on first use.  When the computation
    /// actually runs on the submit path (a collision confirm), callers
    /// pass the fabric's `sha_confirms` counter so the "sha256 only on
    /// collision or first-sight insert" invariant stays measurable.
    fn confirm(&self, confirms: Option<&AtomicU64>) -> [u8; 32] {
        *self.sha.get_or_init(|| {
            if let Some(c) = confirms {
                c.fetch_add(1, Ordering::Relaxed);
            }
            confirm_sha(&self.model, &self.payload)
        })
    }
}

/// In-flight dedup index: tier-1 pre-hash → bucket of executions to
/// piggyback on.  Buckets hold one entry outside forced-collision tests;
/// a follower landing on an occupied bucket confirms by sha256 before
/// attaching, so distinct requests sharing a 64-bit pre-hash never
/// collapse.
type DedupMap = Mutex<HashMap<u64, Vec<Arc<Fanout>>>>;

/// Tier-1 index hash of a routed request: FNV-1a 64 over the model
/// name, a zero separator and the payload's LE bytes — a handful of
/// cycles per element, no allocation, deterministic across runs.  The
/// model name is part of the hash so identical tensors aimed at
/// different AIFs land in different buckets (and the confirm digest
/// separates them exactly even when they do not).
fn prehash(model: &str, payload: &[f32], mask: u64) -> u64 {
    let mut h = Fnv1a::new();
    h.write(model.as_bytes());
    h.write_u8(0);
    for v in payload {
        h.write(&v.to_le_bytes());
    }
    h.finish() & mask
}

/// Tier-2 confirm digest — the exact content address the fabric used to
/// pay per submit, now computed only on pre-hash collision or at the
/// first-sight cache insert.  The model name is part of the digest so
/// identical tensors aimed at different AIFs never collapse.
fn confirm_sha(model: &str, payload: &[f32]) -> [u8; 32] {
    let mut h = Sha256::new();
    h.update(model.as_bytes());
    h.update([0u8]);
    // Stream fixed-size chunks through a stack buffer: no payload-sized
    // allocation.
    let mut buf = [0u8; 4096];
    for chunk in payload.chunks(buf.len() / 4) {
        let mut n = 0;
        for v in chunk {
            buf[n..n + 4].copy_from_slice(&v.to_le_bytes());
            n += 4;
        }
        h.update(&buf[..n]);
    }
    *h.finalize().as_bytes()
}

/// Unregister a completed execution from the dedup index, memoize the
/// response in the cache (when one is configured — dropped if the model
/// was redeployed mid-flight), then fan the outcome out to every waiter
/// (each response re-stamped with the waiter's own request id, each
/// verdict accounted to the waiter's tenant).  Removal happens under
/// the map lock *before* delivery, so a new identical submission either
/// attached in time (and is in `waiters`) or starts a fresh execution —
/// nobody can attach to a completed entry and hang.  Returns the number
/// of waiters delivered to, so fleet counters can stay per-caller
/// consistent with the per-tenant accounting done here.
fn deliver(
    dedup: &DedupMap,
    cache: Option<&ResponseCache>,
    fan: &Arc<Fanout>,
    outcome: Outcome,
) -> u64 {
    if let Some(key) = fan.key {
        {
            // Remove only OUR entry: after `on_artifact_redeploy` purged
            // this execution from the map, an identical post-redeploy
            // submission may have re-registered the same key as a fresh
            // leader — completing here must not evict that live entry.
            let mut map = dedup.lock().unwrap();
            if let Some(bucket) = map.get_mut(&key) {
                if let Some(i) = bucket.iter().position(|entry| Arc::ptr_eq(entry, fan)) {
                    bucket.remove(i);
                }
                if bucket.is_empty() {
                    map.remove(&key);
                }
            }
        }
        if let (Some(c), Outcome::Completed(resp)) = (cache, &outcome) {
            // First-sight insert: the one place the confirm digest is
            // computed off the collision path — and it runs on the
            // delivery side, never on submit.
            c.insert(key, fan.confirm(None), &fan.model, fan.cache_gen, resp.clone());
        }
    }
    let waiters = std::mem::take(&mut *fan.waiters.lock().unwrap());
    let delivered = waiters.len() as u64;
    for (id, tenant, tx) in waiters {
        let personalized = match &outcome {
            Outcome::Completed(resp) => {
                tenant.stats.note_completed(resp.queue_wait_ms + resp.service_ms);
                Outcome::Completed(Response { id, ..resp.clone() })
            }
            Outcome::Failed(e) => {
                tenant.stats.note_failed();
                Outcome::Failed(e.clone())
            }
            Outcome::Shed => {
                tenant.stats.note_preempted();
                Outcome::Shed
            }
        };
        let _ = tx.send(personalized);
    }
    delivered
}

/// Router verdict for one submission.
pub enum Submission {
    /// Admitted (or answered from the cache / an in-flight dedup
    /// attach); the receiver yields the [`Outcome`].  An admitted
    /// request can still be preempted later by higher-priority work, in
    /// which case the receiver yields [`Outcome::Shed`].
    Enqueued(mpsc::Receiver<Outcome>),
    /// Shed at admission: the tenant's quota was exhausted, or every
    /// feasible replica's queue was at the bound with nothing
    /// lower-priority to displace.  Counted either way.
    Shed,
}

struct PodRuntime {
    plan: PodPlan,
    key: String,
    queue: Arc<TenantQueue<Work>>,
    /// Queued + executing requests (router backlog estimate).
    backlog: Arc<AtomicU64>,
    /// `None` once a retired pod has been reaped: the executor (for a
    /// real pod, a compiled model with pinned weights) is the memory a
    /// scale-down exists to release, so it must not live as long as the
    /// fabric.  Workers clone the `Arc` out once at startup.
    executor: Mutex<Option<Arc<dyn PodExecutor>>>,
    /// Adaptive drain-size controller (None with fixed `max_batch`).
    controller: Option<Arc<BatchController>>,
    workers: Mutex<Vec<thread::JoinHandle<()>>>,
    /// Set by the autoscaler: the router skips retired pods; their
    /// queues are closed so workers drain the admitted backlog and exit.
    retired: AtomicBool,
    /// Frozen (snapshot, dispatches) captured when the pod was reaped,
    /// so retired pods keep their report row after the executor is
    /// gone.
    final_report: Mutex<Option<(Snapshot, u64)>>,
    /// Milliseconds after the fabric epoch this pod spawned.
    born_ms: f64,
    /// Milliseconds after the fabric epoch this pod retired, if it did.
    retired_ms: Mutex<Option<f64>>,
    /// Per-pod circuit breaker (None when `resilience.breaker` is off):
    /// executor failures open it, the router stops routing here until
    /// the open window lapses, then half-open probes decide recovery.
    breaker: Option<Mutex<CircuitBreaker>>,
}

impl PodRuntime {
    /// Live (snapshot, dispatch count) while the executor exists, the
    /// frozen reap-time copy afterwards.
    fn stats(&self) -> (Snapshot, u64) {
        if let Some(e) = self.executor.lock().unwrap().as_ref() {
            return (e.collector().snapshot(), e.dispatches());
        }
        self.final_report
            .lock()
            .unwrap()
            .clone()
            .unwrap_or_else(|| (Snapshot::empty(), 0))
    }
}

/// Builds a pod executor for a plan — simulated or real, decided once at
/// `place_*` time and reused by the autoscaler for scale-ups.
type PodFactory = Box<dyn Fn(&PodPlan, &Arc<Artifact>) -> Result<Arc<dyn PodExecutor>> + Send + Sync>;

/// An immutable published view of the pod set: every spawned pod
/// (active and retired) plus the per-model index into it.  Snapshots
/// are never mutated after publication — structural changes (scale-up,
/// reap) build a new snapshot and publish it through [`SnapCell`].
/// In-place pod state (retired flags, breakers, final reports) lives
/// behind each pod's own interior mutability, so flipping it needs no
/// republish.
struct RegistrySnapshot {
    pods: Vec<Arc<PodRuntime>>,
    by_model: BTreeMap<String, Vec<usize>>,
}

/// Epoch-validated snapshot cell: the fabric's RCU-style registry
/// publication point.
///
/// Readers call [`load`](SnapCell::load), which consults a thread-local
/// single-entry cache keyed by `(cell id, epoch)`.  On the steady state
/// (no scale event since this thread's last load) that is two relaxed
/// atomic/TLS reads and **zero shared-lock traffic** — the
/// no-lock-on-submit invariant.  Only when the epoch has moved (a
/// copy-on-write publish happened) does the reader take the brief slot
/// mutex to refresh its cached `Arc`.  Writers serialize structural
/// changes on `FabricInner::registry_write`, build the successor
/// snapshot off to the side, then [`publish`](SnapCell::publish) it:
/// store the new `Arc`, then bump the epoch with `Release` so readers
/// that observe the new epoch also observe the new slot contents.
struct SnapCell {
    /// Process-unique cell id, so a thread's cached entry from one
    /// fabric can never satisfy a load against another.
    id: u64,
    epoch: AtomicU64,
    slot: Mutex<Arc<RegistrySnapshot>>,
}

thread_local! {
    /// One cached `(cell id, epoch, snapshot)` per thread — submit
    /// threads hammer a single fabric, so one entry is a 100% hit rate
    /// in the steady state.
    static SNAP_CACHE: RefCell<Option<(u64, u64, Arc<RegistrySnapshot>)>> =
        const { RefCell::new(None) };
}

impl SnapCell {
    fn new(snap: RegistrySnapshot) -> SnapCell {
        static NEXT_CELL_ID: AtomicU64 = AtomicU64::new(1);
        SnapCell {
            id: NEXT_CELL_ID.fetch_add(1, Ordering::Relaxed),
            epoch: AtomicU64::new(1),
            slot: Mutex::new(Arc::new(snap)),
        }
    }

    /// The current published snapshot (lock-free on the steady state).
    fn load(&self) -> Arc<RegistrySnapshot> {
        let epoch = self.epoch.load(Ordering::Acquire);
        SNAP_CACHE.with(|c| {
            let mut cached = c.borrow_mut();
            if let Some((id, e, snap)) = cached.as_ref() {
                if *id == self.id && *e == epoch {
                    return Arc::clone(snap);
                }
            }
            let snap = Arc::clone(&self.slot.lock().unwrap());
            *cached = Some((self.id, epoch, Arc::clone(&snap)));
            snap
        })
    }

    /// Publish a successor snapshot.  Callers hold
    /// `FabricInner::registry_write` for the whole read-modify-publish,
    /// so publishes never race each other.
    fn publish(&self, snap: RegistrySnapshot) {
        *self.slot.lock().unwrap() = Arc::new(snap);
        self.epoch.fetch_add(1, Ordering::Release);
    }
}

/// Per-model hot-path counters: plain atomics bumped on the submit
/// path, aggregated only at report time.  The model set is fixed at
/// spawn, so the owning map is immutable and lookups are lock-free.
struct ModelCounters {
    /// Requests shed for this model (capacity sheds + preemptions;
    /// quota sheds are tracked fleet-wide and per-tenant, matching the
    /// old `shed_by_model` map's semantics).
    shed: AtomicU64,
    /// Priority-weighted shed pressure (each capacity shed or
    /// preemption adds `1 + priority rank`; the increment is always
    /// integral, so a u64 atomic carries it exactly and the autoscaler
    /// reads it as `f64` at tick time).  Quota sheds add nothing — a
    /// tenant at its own quota is not a capacity problem.
    pressure: AtomicU64,
}

impl ModelCounters {
    fn new() -> ModelCounters {
        ModelCounters { shed: AtomicU64::new(0), pressure: AtomicU64::new(0) }
    }
}

/// Per-model autoscaler bookkeeping.
#[derive(Default)]
struct ModelScale {
    gate: HysteresisGate,
    cooldown: u32,
    /// Cumulative priority-weighted shed pressure at the last tick
    /// (deltas against the model's `ModelCounters::pressure` feed the
    /// window below).
    last_pressure: f64,
    /// Time-windowed shed pressure: each tick folds in the fresh delta
    /// and halves what remains ([`PRESSURE_DECAY`]), so a burst of
    /// storm-induced sheds reads as overload for a bounded number of
    /// ticks and cannot pin the fleet at its scale-up high-water mark
    /// long after recovery.
    windowed_pressure: f64,
}

/// Bit pattern marking an unset lane SLO inside [`LaneSlos`].  It is a
/// NaN encoding, and configured SLOs are validated strictly positive,
/// so no real override can collide with it.
const SLO_NONE: u64 = u64::MAX;

/// Per-lane SLO overrides as live atomics (f64 bit patterns;
/// [`SLO_NONE`] = no override).  Workers read the slots per drained
/// batch, so a `tf2aif apply` SLO edit reaches the batch controllers
/// on the very next cycle — no republish, no restart.  The `active`
/// counter preserves the fast path: with zero overrides configured,
/// workers skip dominant-lane resolution entirely, exactly as the old
/// spawn-time `slos_active` check did.
struct LaneSlos {
    slots: Vec<AtomicU64>,
    active: AtomicUsize,
}

impl LaneSlos {
    fn new(slos: Vec<Option<f64>>) -> LaneSlos {
        let active = slos.iter().filter(|s| s.is_some()).count();
        LaneSlos {
            slots: slos
                .iter()
                .map(|s| AtomicU64::new(s.map_or(SLO_NONE, f64::to_bits)))
                .collect(),
            active: AtomicUsize::new(active),
        }
    }

    /// The lane's current override, if any.
    fn get(&self, lane: usize) -> Option<f64> {
        let bits = self.slots.get(lane)?.load(Ordering::Relaxed);
        (bits != SLO_NONE).then(|| f64::from_bits(bits))
    }

    /// Whether any lane currently carries an override.
    fn any_active(&self) -> bool {
        self.active.load(Ordering::Relaxed) > 0
    }

    /// Install, change or clear one lane's override.
    fn set(&self, lane: usize, slo: Option<f64>) {
        let Some(slot) = self.slots.get(lane) else { return };
        let new = slo.map_or(SLO_NONE, f64::to_bits);
        let old = slot.swap(new, Ordering::Relaxed);
        match (old != SLO_NONE, new != SLO_NONE) {
            (false, true) => {
                self.active.fetch_add(1, Ordering::Relaxed);
            }
            (true, false) => {
                self.active.fetch_sub(1, Ordering::Relaxed);
            }
            _ => {}
        }
    }
}

/// Autoscaler state: its own (feedback-blended) placement backend plus
/// hysteresis counters and the scale-event log.
struct ScalerState {
    /// Bounds + cadence, behind a mutex so `tf2aif apply` can retune
    /// min/max replicas live (the tick clones it once per step; the
    /// spawn-time thread interval is read once and is not live-tunable).
    auto: Mutex<AutoscaleConfig>,
    backend: Backend,
    per_model: Mutex<BTreeMap<String, ModelScale>>,
    events: Mutex<Vec<ScaleEvent>>,
    ups: AtomicU64,
    downs: AtomicU64,
    /// Most recent pod-spawn failure (factory error at scale-up) —
    /// surfaced via [`Fabric::last_scale_error`] so a wedged scale-up
    /// is diagnosable instead of silent.
    last_spawn_error: Mutex<Option<String>>,
}

/// Shared fabric state: the router, every pod, and the control plane.
struct FabricInner {
    /// The published pod-set snapshot (see [`SnapCell`]): submits load
    /// it lock-free; structural changes publish copy-on-write.
    registry: SnapCell,
    /// Serializes structural registry changes (scale-up, reap).  Held
    /// only by control-plane writers — the submit path never touches
    /// it.
    registry_write: Mutex<()>,
    input_shapes: BTreeMap<String, (usize, usize, usize)>,
    feedback: Arc<FeedbackStore>,
    cfg: FabricConfig,
    /// The tenant set (specs resolved to lanes + live quota buckets).
    tenants: TenantRegistry,
    /// Lane layout shared by every pod queue (computed once from the
    /// tenant registry and `queue_capacity`; reused at scale-up).
    lanes: Vec<LaneConfig>,
    /// Per-lane SLO overrides: a drained batch dominated by lane `i`
    /// backs its pod's adaptive controller off against the lane's
    /// override (when set) instead of the fabric-wide `slo_p99_ms`.
    /// Live atomics — see [`LaneSlos`].
    lane_slos: LaneSlos,
    /// Per-model offered-arrival EWMAs (every submission counts, admitted
    /// or not) — the predictive autoscaler's demand signal.  Built once
    /// at spawn (the model set is fixed; the autoscaler only adds
    /// replicas of existing models) and empty unless predictive scaling
    /// is configured, so the admission path pays at most one lock-free
    /// map lookup plus the estimator's own mutex.
    arrivals: BTreeMap<String, ArrivalRate>,
    /// The cluster the fabric owns: autoscaler binds/terminates pods
    /// against the same slot and memory accounting placement used.
    cluster: Mutex<Cluster>,
    factory: PodFactory,
    scaler: Option<ScalerState>,
    cache: Option<Arc<ResponseCache>>,
    /// Birth instant; scale events and pod lifetimes are offsets from it.
    epoch: Instant,
    next_id: AtomicU64,
    /// Every shed, whatever the reason (quota, capacity, preemption) —
    /// the receiver-side accounting invariant: `completed + failed +
    /// shed == submitted`.
    shed_total: AtomicU64,
    /// Quota (token-bucket) sheds — policy rejections, split out so
    /// they never read as capacity pressure.
    quota_shed_total: AtomicU64,
    /// Queued requests evicted by higher-priority work.
    preempted_total: AtomicU64,
    /// Per-model shed + autoscaler-pressure atomics (see
    /// [`ModelCounters`]).  Built once at spawn from the fixed model
    /// set, so the submit path pays a lock-free map lookup and an
    /// atomic add — never a registry-wide mutex.
    model_stats: BTreeMap<String, ModelCounters>,
    /// In-flight dedup index, shared with every pod worker.
    dedup: Arc<DedupMap>,
    dedup_hits: AtomicU64,
    /// sha256 confirm digests actually computed on the submit path
    /// (pre-hash bucket occupied, so tier 2 ran).  The hotpath bench
    /// reads this to prove the two-tier scheme works: distinct-payload
    /// traffic must keep it at zero.
    sha_confirms: AtomicU64,
    /// Executor-failure retries re-routed under the resilience policy.
    retries_total: AtomicU64,
    /// Faults injected into this fabric (pod crashes on the threaded
    /// path; the virtual-time engine tracks its own).
    faults_injected: AtomicU64,
    stop: AtomicBool,
}

/// The serving fabric: every placed pod plus the router and control
/// plane.  All methods are callable while traffic flows.
pub struct Fabric {
    inner: Arc<FabricInner>,
    scaler_thread: Option<thread::JoinHandle<()>>,
}

/// Plan replica placements for every model the backend knows, binding
/// pods through the cluster scheduler (filter → score → bind).  Ranking
/// is refreshed per model so later models see earlier binds' slot and
/// memory consumption; a rank entry whose capacity raced away simply
/// fails its bind and the next candidate is tried.
fn plan_placements(
    backend: &Backend,
    cluster: &mut Cluster,
    replicas: usize,
) -> Result<Vec<(PodPlan, Arc<Artifact>)>> {
    let models: Vec<String> = backend.models().iter().map(|m| m.to_string()).collect();
    if models.is_empty() {
        bail!("backend has no models to place");
    }
    let mut out = Vec::new();
    for model in &models {
        let mut nodes_used: BTreeSet<String> = BTreeSet::new();
        let ranked = backend.rank(model, cluster)?;
        for d in ranked {
            if nodes_used.len() >= replicas.max(1) {
                break;
            }
            if nodes_used.contains(&d.node) {
                continue;
            }
            // Shared (`Arc`) with the pod executor and the runtime host
            // from here on — a refcount bump, never a weight-byte clone.
            let artifact = Arc::clone(
                backend
                    .variants_of(model)
                    .into_iter()
                    .find(|a| a.manifest.variant == d.variant)
                    .context("ranked variant missing from index")?,
            );
            let mem = Backend::pod_memory_gb(&artifact);
            let Ok(pod_id) = cluster.bind(&d.aif, &d.variant, &d.node, mem) else {
                continue; // capacity raced away since ranking
            };
            nodes_used.insert(d.node.clone());
            out.push((
                PodPlan {
                    aif: d.aif.clone(),
                    model: model.clone(),
                    variant: d.variant.clone(),
                    node: d.node.clone(),
                    pod_id,
                    modeled_ms: d.modeled_ms,
                },
                artifact,
            ));
        }
        if nodes_used.is_empty() {
            bail!("no feasible placement for model {model:?}");
        }
    }
    Ok(out)
}

/// A full catalog snapshot of a backend's artifact index — what the
/// autoscaler ranks scale-up placements from.  Shared handles: cloning
/// the snapshot bumps refcounts, never weight bytes.
fn catalog_of(backend: &Backend) -> Vec<Arc<Artifact>> {
    backend
        .models()
        .into_iter()
        .flat_map(|m| backend.variants_of(m).into_iter().map(Arc::clone))
        .collect()
}

/// Everything `Fabric::spawn` needs beyond the pods themselves.
struct SpawnEnv {
    cluster: Cluster,
    factory: PodFactory,
    catalog: Vec<Arc<Artifact>>,
    policy: Policy,
    allow_native: bool,
    predictor: Option<crate::backend::predictor::LearnedLatency>,
}

impl SpawnEnv {
    fn from_backend(backend: &Backend, cluster: Cluster, factory: PodFactory) -> SpawnEnv {
        SpawnEnv {
            cluster,
            factory,
            catalog: catalog_of(backend),
            policy: backend.policy,
            allow_native: backend.allow_native,
            predictor: backend.predictor.clone(),
        }
    }
}

impl Fabric {
    /// Place and spawn the fabric with **simulated** pods (platform cost
    /// models; no artifacts or PJRT needed).  The fabric takes ownership
    /// of the cluster so its autoscaler can bind and terminate pods
    /// against live slot/memory accounting; inspect it later through
    /// [`with_cluster`](Self::with_cluster).  `gate`, when provided, is
    /// installed in every pod (including autoscaled ones) for
    /// deterministic overload tests.
    pub fn place_sim(
        backend: &Backend,
        mut cluster: Cluster,
        cfg: &FabricConfig,
        gate: Option<Arc<Gate>>,
    ) -> Result<Fabric> {
        let plans = plan_placements(backend, &mut cluster, cfg.replicas_per_model)?;
        let time_scale = cfg.time_scale;
        let seed = cfg.seed;
        let factory: PodFactory = Box::new(move |plan, artifact| {
            let pod = SimPod::new(
                &plan.variant,
                artifact.manifest.gflops,
                time_scale,
                seed ^ plan.pod_id,
                gate.clone(),
            )?;
            Ok(Arc::new(pod) as Arc<dyn PodExecutor>)
        });
        let mut pods = Vec::new();
        for (plan, artifact) in plans {
            let executor = (factory)(&plan, &artifact)?;
            pods.push((plan, artifact, executor));
        }
        let env = SpawnEnv::from_backend(backend, cluster, factory);
        Fabric::spawn(pods, cfg.clone(), env)
    }

    /// Place and spawn the fabric with **zero-work** pods
    /// ([`NullPod`]): requests complete the instant a worker drains
    /// them, so a saturation drive measures pure submit→verdict
    /// router/queue/dedup overhead.  This is the `tf2aif bench
    /// --hotpath` harness's executor; placement, queues, tenancy,
    /// dedup and caching all behave exactly as in the other modes.
    pub fn place_null(
        backend: &Backend,
        mut cluster: Cluster,
        cfg: &FabricConfig,
    ) -> Result<Fabric> {
        let plans = plan_placements(backend, &mut cluster, cfg.replicas_per_model)?;
        let factory: PodFactory =
            Box::new(move |_plan, _artifact| Ok(Arc::new(NullPod::new()) as Arc<dyn PodExecutor>));
        let mut pods = Vec::new();
        for (plan, artifact) in plans {
            let executor = (factory)(&plan, &artifact)?;
            pods.push((plan, artifact, executor));
        }
        let env = SpawnEnv::from_backend(backend, cluster, factory);
        Fabric::spawn(pods, cfg.clone(), env)
    }

    /// Place and spawn the fabric with **real** pods: one compiled,
    /// weight-pinned [`AifServer`] per placement (requires on-disk
    /// artifacts).  The engine handle is kept so the autoscaler can
    /// compile additional replicas at scale-up time.
    pub fn place_real(
        backend: &Backend,
        mut cluster: Cluster,
        engine: Engine,
        cfg: &FabricConfig,
    ) -> Result<Fabric> {
        let plans = plan_placements(backend, &mut cluster, cfg.replicas_per_model)?;
        // `Engine` is Send but not Sync (a channel handle to the runtime
        // host); the mutex makes the factory shareable with the control
        // thread.
        let engine = Mutex::new(engine);
        let factory: PodFactory = Box::new(move |_plan, artifact| {
            let engine = engine.lock().unwrap();
            let server = AifServer::deploy(&engine, artifact, Arc::new(ImageClassify))?;
            Ok(Arc::new(server) as Arc<dyn PodExecutor>)
        });
        let mut pods = Vec::new();
        for (plan, artifact) in plans {
            let executor = (factory)(&plan, &artifact)?;
            pods.push((plan, artifact, executor));
        }
        let env = SpawnEnv::from_backend(backend, cluster, factory);
        Fabric::spawn(pods, cfg.clone(), env)
    }

    fn spawn(
        pods: Vec<(PodPlan, Arc<Artifact>, Arc<dyn PodExecutor>)>,
        cfg: FabricConfig,
        env: SpawnEnv,
    ) -> Result<Fabric> {
        // Tenant misconfiguration (zero quota, bad share, duplicates)
        // surfaces here as a typed error, before any thread spawns.
        let tenants = TenantRegistry::build(&cfg.tenants).map_err(anyhow::Error::new)?;
        let lanes = tenants.lane_configs(cfg.queue_capacity);
        let lane_slos = LaneSlos::new(tenants.lane_slos());
        let feedback = Arc::new(FeedbackStore::new(cfg.feedback_alpha));
        let cache = (cfg.cache_capacity > 0).then(|| {
            Arc::new(ResponseCache::new(
                cfg.cache_capacity,
                Duration::from_millis(cfg.cache_ttl_ms),
            ))
        });
        let scaler = cfg.autoscale.clone().map(|auto| {
            // The scaler ranks scale-up placements with its own backend
            // over the same catalog, wired to the live feedback store —
            // so replicas land where measured (not just modeled)
            // latency says they should.
            let mut backend = Backend::from_shared(env.catalog.clone(), env.policy);
            backend.allow_native = env.allow_native;
            // Same ranking inputs as the placing backend: learned
            // predictor (when trained) AND the live feedback store —
            // scale-ups must not silently rank by a different cost
            // model than initial placement did.
            backend.predictor = env.predictor.clone();
            backend.feedback = Some(Arc::clone(&feedback));
            ScalerState {
                auto: Mutex::new(auto),
                backend,
                per_model: Mutex::new(BTreeMap::new()),
                events: Mutex::new(Vec::new()),
                ups: AtomicU64::new(0),
                downs: AtomicU64::new(0),
                last_spawn_error: Mutex::new(None),
            }
        });
        let epoch = Instant::now();
        let mut registry = RegistrySnapshot { pods: Vec::new(), by_model: BTreeMap::new() };
        let mut input_shapes = BTreeMap::new();
        for (plan, artifact, executor) in pods {
            let s = &artifact.manifest.input_shape;
            if s.len() == 4 {
                input_shapes.entry(plan.model.clone()).or_insert((s[1], s[2], s[3]));
            }
            let idx = registry.pods.len();
            registry.by_model.entry(plan.model.clone()).or_default().push(idx);
            registry.pods.push(Arc::new(new_runtime(plan, executor, &cfg, 0.0, &lanes)));
        }
        // The model set is fixed from here on (the autoscaler only adds
        // replicas of existing models), so the per-model counter map is
        // immutable and submit-path lookups are lock-free.
        let model_stats: BTreeMap<String, ModelCounters> = registry
            .by_model
            .keys()
            .map(|m| (m.clone(), ModelCounters::new()))
            .collect();
        // One estimator per model, up front: the model set never grows
        // after spawn, so the admission path reads an immutable map.
        let arrivals: BTreeMap<String, ArrivalRate> =
            if cfg.autoscale.as_ref().map_or(false, |a| a.predictive) {
                registry
                    .by_model
                    .keys()
                    .map(|m| (m.clone(), ArrivalRate::new(0.2)))
                    .collect()
            } else {
                BTreeMap::new()
            };
        let inner = Arc::new(FabricInner {
            registry: SnapCell::new(registry),
            registry_write: Mutex::new(()),
            input_shapes,
            feedback,
            cfg,
            tenants,
            lanes,
            lane_slos,
            arrivals,
            cluster: Mutex::new(env.cluster),
            factory: env.factory,
            scaler,
            cache,
            epoch,
            next_id: AtomicU64::new(0),
            shed_total: AtomicU64::new(0),
            quota_shed_total: AtomicU64::new(0),
            preempted_total: AtomicU64::new(0),
            model_stats,
            dedup: Arc::new(Mutex::new(HashMap::new())),
            dedup_hits: AtomicU64::new(0),
            sha_confirms: AtomicU64::new(0),
            retries_total: AtomicU64::new(0),
            faults_injected: AtomicU64::new(0),
            stop: AtomicBool::new(false),
        });
        // Iterate the published snapshot — no pod-vector clone.
        let initial = inner.registry.load();
        for pod in &initial.pods {
            start_workers(&inner, pod);
        }
        let interval_ms =
            inner.scaler.as_ref().map_or(0, |sc| sc.auto.lock().unwrap().interval_ms);
        let scaler_thread = (interval_ms > 0).then(|| {
            let inner = Arc::clone(&inner);
            let interval = Duration::from_millis(interval_ms);
            thread::spawn(move || {
                while !inner.stop.load(Ordering::Relaxed) {
                    autoscale_tick(&inner);
                    thread::sleep(interval);
                }
            })
        });
        Ok(Fabric { inner, scaler_thread })
    }

    /// The shared feedback store (attach it to a
    /// [`Backend`](crate::backend::Backend) via its `feedback` field so
    /// future placements see fabric measurements).
    pub fn feedback(&self) -> Arc<FeedbackStore> {
        Arc::clone(&self.inner.feedback)
    }

    /// The configuration the fabric was spawned with.
    pub fn config(&self) -> &FabricConfig {
        &self.inner.cfg
    }

    /// Every spawned pod's plan, in spawn order (includes pods the
    /// autoscaler has since retired — the full replica timeline).
    pub fn plans(&self) -> Vec<PodPlan> {
        self.inner.registry.load().pods.iter().map(|p| p.plan.clone()).collect()
    }

    /// Distinct cluster nodes hosting at least one **active** pod.
    pub fn nodes_spanned(&self) -> BTreeSet<String> {
        self.inner
            .registry
            .load()
            .pods
            .iter()
            .filter(|p| !p.retired.load(Ordering::Relaxed))
            .map(|p| p.plan.node.clone())
            .collect()
    }

    /// Models the fabric can route.
    pub fn models(&self) -> Vec<String> {
        self.inner.registry.load().by_model.keys().cloned().collect()
    }

    /// NHWC input shape for a model's requests, from its placed artifact.
    pub fn input_shape(&self, model: &str) -> Option<(usize, usize, usize)> {
        self.inner.input_shapes.get(model).copied()
    }

    /// Active (non-retired) replicas of a model right now.
    pub fn active_replicas(&self, model: &str) -> usize {
        let reg = self.inner.registry.load();
        reg.by_model.get(model).map_or(0, |idxs| {
            idxs.iter().filter(|&&i| !reg.pods[i].retired.load(Ordering::Relaxed)).count()
        })
    }

    /// Route one request for `model` on behalf of the
    /// [`DEFAULT_TENANT`]: check the tenant's quota, consult the
    /// response cache (a fresh identical response answers immediately),
    /// collapse onto an identical in-flight request when dedup is on,
    /// otherwise try the replicas in ascending score order, admit into
    /// the first queue with room at this tenant's priority (possibly
    /// preempting strictly-lower-priority queued work), and shed if
    /// every queue is at the bound.  Shed requests are counted —
    /// nothing is silently dropped.
    /// Payloads are shared end-to-end as `Arc<[f32]>` (queue staging,
    /// dedup fan-out, response cache, retry re-routing all bump a
    /// refcount); `Vec<f32>` call sites convert implicitly via
    /// `impl Into<Arc<[f32]>>`, and callers holding an `Arc` pay
    /// nothing.
    pub fn submit(&self, model: &str, payload: impl Into<Arc<[f32]>>) -> Result<Submission> {
        self.inner.submit_as(DEFAULT_TENANT, model, payload.into())
    }

    /// [`submit`](Self::submit) on behalf of a named tenant.  An
    /// unknown tenant id is a typed error
    /// ([`TenancyError::UnknownTenant`], downcastable), never a panic
    /// and never a silent drop.
    pub fn submit_as(
        &self,
        tenant: &str,
        model: &str,
        payload: impl Into<Arc<[f32]>>,
    ) -> Result<Submission> {
        self.inner.submit_as(tenant, model, payload.into())
    }

    /// Per-tenant report rows (configuration + every admission verdict
    /// + completed-latency percentiles), in lane order.
    pub fn tenant_reports(&self) -> Vec<TenantReport> {
        self.inner.tenants.all().iter().map(|t| TenantReport::from_state(t)).collect()
    }

    /// Artifact-redeploy hook: call after re-generating or re-deploying
    /// `model`'s artifact.  Bumps the model's response-cache generation
    /// (no cached pre-redeploy response can be served again, and a memo
    /// from an execution still in flight is dropped on insert) and
    /// purges the model's in-flight dedup entries so new identical
    /// submissions execute fresh instead of piggybacking on a
    /// pre-redeploy execution.  Callers already attached keep their
    /// in-flight result — they submitted before the redeploy.
    pub fn on_artifact_redeploy(&self, model: &str) {
        if let Some(cache) = &self.inner.cache {
            cache.invalidate(model);
        }
        let mut dedup = self.inner.dedup.lock().unwrap();
        for bucket in dedup.values_mut() {
            bucket.retain(|fan| fan.model != model);
        }
        dedup.retain(|_, bucket| !bucket.is_empty());
    }

    /// Live-edit a tenant's rate quota without restarting the fabric.
    /// `Some(rate)` installs or reshapes the tenant's token bucket
    /// (already-accrued tokens are clamped to the new burst and the
    /// refill clock is preserved — the edit never mints retroactive
    /// credit); `None` removes the quota so the tenant is admitted
    /// unconditionally.  In-flight and queued requests are untouched.
    /// Unknown tenants and non-positive rates are typed errors
    /// ([`TenancyError`], downcastable).
    pub fn set_tenant_quota(
        &self,
        tenant: &str,
        rate_rps: Option<f64>,
        burst: f64,
    ) -> Result<()> {
        if let Some(rate) = rate_rps {
            if rate <= 0.0 {
                return Err(anyhow::Error::new(TenancyError::ZeroQuota(tenant.to_string())));
            }
            if burst < 1.0 {
                return Err(anyhow::Error::new(TenancyError::Malformed {
                    entry: tenant.to_string(),
                    reason: format!("burst {burst} must admit at least one request"),
                }));
            }
        }
        let t = self
            .inner
            .tenants
            .get(tenant)
            .ok_or_else(|| anyhow::Error::new(TenancyError::UnknownTenant(tenant.to_string())))?
            .clone();
        t.set_quota(rate_rps, burst);
        Ok(())
    }

    /// Live-edit a tenant's p99 latency SLO.  `Some(ms)` (strictly
    /// positive) makes batches dominated by this tenant back off
    /// against the new target from the next controller cycle;
    /// `None` clears the override so the global feedback target
    /// applies again.  Workers observe the edit without restarting —
    /// the per-lane slot is a lock-free atomic.
    pub fn set_tenant_slo(&self, tenant: &str, slo_p99_ms: Option<f64>) -> Result<()> {
        if let Some(slo) = slo_p99_ms {
            if slo <= 0.0 {
                return Err(anyhow::Error::new(TenancyError::Malformed {
                    entry: tenant.to_string(),
                    reason: format!("slo_ms {slo} must be positive"),
                }));
            }
        }
        let lane = self
            .inner
            .tenants
            .get(tenant)
            .ok_or_else(|| anyhow::Error::new(TenancyError::UnknownTenant(tenant.to_string())))?
            .lane;
        self.inner.lane_slos.set(lane, slo_p99_ms);
        Ok(())
    }

    /// Live-edit the response cache's freshness TTL.  Takes effect on
    /// the next lookup: a shorter TTL immediately expires entries that
    /// were stored under the longer one.  Returns `false` (and does
    /// nothing) when the fabric was built without a cache —
    /// `cache_capacity: 0` — so callers can surface the no-op instead
    /// of silently accepting a dead knob.
    pub fn set_cache_ttl(&self, ttl: Duration) -> bool {
        match &self.inner.cache {
            Some(cache) => {
                cache.set_ttl(ttl);
                true
            }
            None => false,
        }
    }

    /// Live-edit the autoscaler's replica bounds.  The next
    /// [`autoscale_tick`](Self::autoscale_tick) (or background scaler
    /// cycle) plans against the new envelope: a fleet above
    /// `max_replicas` scales down on the usual hysteresis schedule,
    /// never abruptly.  Errors when the fabric has no autoscaler or
    /// the bounds are inverted/zero.
    pub fn set_autoscale_bounds(&self, min_replicas: usize, max_replicas: usize) -> Result<()> {
        if min_replicas == 0 || max_replicas < min_replicas {
            bail!(
                "autoscale bounds must satisfy 1 <= min <= max \
                 (got min={min_replicas} max={max_replicas})"
            );
        }
        let Some(sc) = &self.inner.scaler else {
            bail!("fabric has no autoscaler (spawn with FabricConfig.autoscale)");
        };
        let mut auto = sc.auto.lock().unwrap();
        auto.min_replicas = min_replicas;
        auto.max_replicas = max_replicas;
        Ok(())
    }

    /// Total shed requests so far (quota + capacity + preemptions).
    pub fn shed_total(&self) -> u64 {
        self.inner.shed_total.load(Ordering::Relaxed)
    }

    /// Submissions shed by per-tenant token-bucket quotas.
    pub fn quota_shed_total(&self) -> u64 {
        self.inner.quota_shed_total.load(Ordering::Relaxed)
    }

    /// Callers whose admitted request was evicted by higher-priority
    /// work (dedup'd followers of an evicted leader each count — the
    /// fleet total matches the per-tenant `preempted` columns).
    pub fn preempted_total(&self) -> u64 {
        self.inner.preempted_total.load(Ordering::Relaxed)
    }

    /// Submissions that collapsed onto an identical in-flight request
    /// (served by memoized fan-out instead of a fresh execution).
    pub fn dedup_hits(&self) -> u64 {
        self.inner.dedup_hits.load(Ordering::Relaxed)
    }

    /// Shed counts per model, aggregated from the per-model atomics at
    /// call time (models with zero sheds are omitted, matching the old
    /// lazily-populated map).
    pub fn shed_by_model(&self) -> BTreeMap<String, u64> {
        self.inner
            .model_stats
            .iter()
            .filter_map(|(m, c)| {
                let n = c.shed.load(Ordering::Relaxed);
                (n > 0).then(|| (m.clone(), n))
            })
            .collect()
    }

    /// sha256 confirm digests computed on the submit path so far (the
    /// two-tier hashing tier-2 counter — stays 0 for distinct-payload
    /// traffic with no cache/dedup index hits).
    pub fn sha_confirms(&self) -> u64 {
        self.inner.sha_confirms.load(Ordering::Relaxed)
    }

    /// Response-cache counters (None when the cache is disabled).
    pub fn cache_stats(&self) -> Option<CacheStats> {
        self.inner.cache.as_ref().map(|c| c.stats())
    }

    /// Most recent autoscaler pod-spawn failure, if any (None when
    /// autoscaling is off or every spawn succeeded).
    pub fn last_scale_error(&self) -> Option<String> {
        self.inner
            .scaler
            .as_ref()
            .and_then(|s| s.last_spawn_error.lock().unwrap().clone())
    }

    /// Executor-failure retries re-routed under the resilience policy.
    pub fn retries_total(&self) -> u64 {
        self.inner.retries_total.load(Ordering::Relaxed)
    }

    /// Faults injected into this fabric so far (pod crashes via
    /// [`inject_pod_crash`](Self::inject_pod_crash) /
    /// [`schedule_faults`](Self::schedule_faults)).
    pub fn faults_injected(&self) -> u64 {
        self.inner.faults_injected.load(Ordering::Relaxed)
    }

    /// Circuit-breaker trips across every pod spawned so far (0 when
    /// breakers are off).
    pub fn breaker_trips(&self) -> u64 {
        self.inner
            .registry
            .load()
            .pods
            .iter()
            .filter_map(|p| p.breaker.as_ref())
            .map(|b| b.lock().unwrap().trips())
            .sum()
    }

    /// Chaos hook: crash the `idx`-th spawned pod (spawn order, as in
    /// [`plans`](Self::plans)).  The pod is retired and its breaker
    /// opened immediately; its queued work is seized and re-routed to
    /// surviving replicas under the retry policy, with a terminal
    /// [`Outcome::Failed`] for anything no replica admits — dedup'd
    /// followers attached to a seized leader get the leader's verdict,
    /// so no waiter ever hangs.  Items a worker already drained finish
    /// executing normally (the virtual-time engine models the mid-batch
    /// kill exactly).  Returns the number of queued items seized, or
    /// `None` when `idx` is out of range.
    pub fn inject_pod_crash(&self, idx: usize) -> Option<usize> {
        let pod = self.inner.registry.load().pods.get(idx).cloned()?;
        if pod.retired.load(Ordering::Relaxed) {
            return Some(0);
        }
        Some(self.inner.crash_pod(&pod))
    }

    /// Replay a [`FaultPlan`]'s pod crashes against the live fabric on a
    /// background thread, each fault's `at_s` scaled by `time_scale`
    /// into real sleep (the same compression `FabricConfig::time_scale`
    /// applies to service latencies).  The threaded path replays
    /// **crashes only** — stragglers, link faults and site flaps are
    /// topology-level effects modeled on the deterministic virtual-time
    /// path (`tf2aif fabric --virtual-time --faults ...`).  A crash's
    /// `site` is matched against cluster node names; `pod` indexes the
    /// node's active pods in spawn order.
    pub fn schedule_faults(&self, plan: &FaultPlan, time_scale: f64) -> thread::JoinHandle<()> {
        let mut crashes: Vec<(f64, String, usize)> = plan
            .faults
            .iter()
            .filter_map(|f| match f {
                Fault::PodCrash { at_s, site, pod, .. } => Some((*at_s, site.clone(), *pod)),
                _ => None,
            })
            .collect();
        crashes.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let inner = Arc::clone(&self.inner);
        thread::spawn(move || {
            let t0 = Instant::now();
            for (at_s, node, nth) in crashes {
                let target = Duration::from_secs_f64((at_s * time_scale).max(0.0));
                if let Some(left) = target.checked_sub(t0.elapsed()) {
                    thread::sleep(left);
                }
                if inner.stop.load(Ordering::Relaxed) {
                    return;
                }
                let victim = inner
                    .registry
                    .load()
                    .pods
                    .iter()
                    .filter(|p| p.plan.node == node && !p.retired.load(Ordering::Relaxed))
                    .nth(nth)
                    .cloned();
                if let Some(pod) = victim {
                    inner.crash_pod(&pod);
                }
            }
        })
    }

    /// Every autoscaler action so far, oldest first.
    pub fn scale_events(&self) -> Vec<ScaleEvent> {
        self.inner
            .scaler
            .as_ref()
            .map(|s| s.events.lock().unwrap().clone())
            .unwrap_or_default()
    }

    /// Current adaptive drain-size target per active pod, as
    /// `(feedback key, target)` pairs (empty with `adaptive` off).
    pub fn batch_targets(&self) -> Vec<(String, usize)> {
        self.inner
            .registry
            .load()
            .pods
            .iter()
            .filter(|p| !p.retired.load(Ordering::Relaxed))
            .filter_map(|p| p.controller.as_ref().map(|c| (p.key.clone(), c.target())))
            .collect()
    }

    /// Run one autoscaler control step now.  This is the same function
    /// the background control thread calls every `interval_ms`; with
    /// `interval_ms == 0` it is the ONLY driver, which is what the
    /// deterministic tests use.  No-op when autoscaling is off.
    pub fn autoscale_tick(&self) {
        autoscale_tick(&self.inner);
    }

    // ── live-migration hooks (see docs/ARCHITECTURE.md §Live migration) ──

    /// Export `model`'s live response-cache entries for a warm
    /// migration handover (empty when the cache is off or cold).  The
    /// local cache is left untouched — the source keeps serving until
    /// its drain completes.
    pub fn export_cache(&self, model: &str) -> Vec<CacheExport> {
        self.inner.cache.as_ref().map(|c| c.export_model(model)).unwrap_or_default()
    }

    /// Import cache entries exported from a source site's fabric,
    /// stored under *this* fabric's current generation for `model` with
    /// their source age (and hence remaining TTL) preserved.  Returns
    /// how many entries landed (0 when the cache is off).
    pub fn import_cache(&self, model: &str, entries: &[CacheExport]) -> usize {
        self.inner.cache.as_ref().map(|c| c.import_model(model, entries)).unwrap_or(0)
    }

    /// Spawn one more replica of `model` through the autoscaler's
    /// placement path (feedback-blended ranking, distinct nodes,
    /// per-platform ceilings), logging a [`ScaleEvent`] with `trigger`.
    /// This is the migration target's "spawn the replacement pod" step.
    /// Returns `false` when no placement fits — or when the fabric was
    /// spawned without `autoscale` (the scaler owns the placement
    /// backend).
    pub fn add_replica(&self, model: &str, trigger: &str) -> bool {
        let Some(sc) = self.inner.scaler.as_ref() else {
            return false;
        };
        let active = self.active_replicas(model);
        scale_up(&self.inner, model, sc, active, trigger)
    }

    /// Gracefully retire one active replica of `model` (the
    /// worst-estimated one, as the autoscaler's scale-down picks): the
    /// router stops seeing it immediately, its workers drain everything
    /// already admitted, and the cluster slot is released.  Admitted
    /// work is never dropped — that is the migration source's
    /// zero-drop handoff step.  Requires `autoscale` like
    /// [`add_replica`](Self::add_replica).
    pub fn retire_replica(&self, model: &str, trigger: &str) -> bool {
        let Some(sc) = self.inner.scaler.as_ref() else {
            return false;
        };
        let active = self.active_replicas(model);
        if active == 0 {
            return false;
        }
        self.inner.scale_down(model, sc, active, trigger)
    }

    /// Reap retired pods whose workers have finished draining (join
    /// threads, freeze reports, release executors).  The autoscaler's
    /// control thread does this every tick; migration calls it
    /// explicitly after the source drain so the handover ends with the
    /// source's memory actually reclaimed.
    pub fn reap_retired(&self) {
        self.inner.reap_retired();
    }

    /// Offered-arrival EWMA for `model`, requests/second (None until
    /// the predictive autoscaler has seen enough arrivals, or when
    /// `autoscale.predictive` is off).  The continuum migration policy
    /// reads these forecasts to shift capacity toward rising demand.
    pub fn arrival_rate_rps(&self, model: &str) -> Option<f64> {
        self.inner.arrivals.get(model).and_then(|a| a.rate_rps())
    }

    /// Inspect the fabric-owned cluster (placement accounting, pod
    /// states) without exposing the lock.
    pub fn with_cluster<R>(&self, f: impl FnOnce(&Cluster) -> R) -> R {
        f(&self.inner.cluster.lock().unwrap())
    }

    /// Drive a workload through the router: `requests` synthetic
    /// image-classification requests spread round-robin over `models`
    /// (all placed models when empty), paced by `arrival`.
    ///
    /// `Arrival::ClosedLoop` keeps exactly one request outstanding (the
    /// paper's benchmark semantics, matching the single-AIF
    /// [`Client`](crate::client::Client) driver — shedding cannot occur).
    /// Open-loop arrivals submit asynchronously; real sleep per gap is
    /// capped at 2 ms, mirroring the client driver.
    pub fn run(&self, requests: usize, arrival: Arrival, seed: u64) -> Result<FabricRunReport> {
        self.run_with(requests, arrival, seed, |rng: &mut Rng, model: &str, _i: usize| {
            let (h, w, c) = self.input_shape(model).unwrap_or((8, 8, 1));
            image_like(rng, h, w, c).into()
        })
    }

    /// [`run`](Self::run) with a caller-supplied payload source — the
    /// single drive loop shared by `tf2aif fabric` (fresh image-like
    /// payloads) and the `tf2aif bench` sweep (pre-generated payload
    /// pool), so pacing and accounting can never diverge between them.
    /// `payload_for` receives the workload RNG, the target model and the
    /// request index; it returns the shared payload handle (a pool hands
    /// out `Arc::clone`s, a generator converts its fresh `Vec` once).
    pub fn run_with(
        &self,
        requests: usize,
        arrival: Arrival,
        seed: u64,
        payload_for: impl FnMut(&mut Rng, &str, usize) -> Arc<[f32]>,
    ) -> Result<FabricRunReport> {
        self.run_with_tenants(requests, arrival, seed, payload_for, |_| {
            DEFAULT_TENANT.to_string()
        })
    }

    /// Drive a multi-tenant workload: image-like payloads, requests
    /// attributed to tenants by the deterministic weighted interleave
    /// of `mix` (see [`TenantMix`](crate::workload::TenantMix)).
    pub fn run_tenants(
        &self,
        requests: usize,
        arrival: Arrival,
        seed: u64,
        mix: &crate::workload::TenantMix,
    ) -> Result<FabricRunReport> {
        self.run_with_tenants(
            requests,
            arrival,
            seed,
            |rng: &mut Rng, model: &str, _i: usize| {
                let (h, w, c) = self.input_shape(model).unwrap_or((8, 8, 1));
                image_like(rng, h, w, c).into()
            },
            |i| mix.pick(i).to_string(),
        )
    }

    /// The fully general drive loop: caller-supplied payload source AND
    /// tenant attribution per request index.  Everything every other
    /// `run*` method does funnels through here.
    pub fn run_with_tenants(
        &self,
        requests: usize,
        arrival: Arrival,
        seed: u64,
        mut payload_for: impl FnMut(&mut Rng, &str, usize) -> Arc<[f32]>,
        mut tenant_for: impl FnMut(usize) -> String,
    ) -> Result<FabricRunReport> {
        let models = self.models();
        if models.is_empty() {
            bail!("fabric has no pods");
        }
        let closed_loop = arrival == Arrival::ClosedLoop;
        let mut rng = Rng::new(seed);
        let t0 = Instant::now();
        let mut pending = Vec::new();
        let mut shed = 0usize;
        let mut completed = 0usize;
        let mut failed = 0usize;
        let mut e2e_ms = Series::new();
        fn account(
            outcome: Option<Outcome>,
            completed: &mut usize,
            failed: &mut usize,
            shed: &mut usize,
            e2e_ms: &mut Series,
        ) {
            match outcome {
                Some(Outcome::Completed(resp)) => {
                    *completed += 1;
                    e2e_ms.push(resp.queue_wait_ms + resp.service_ms);
                }
                // Admitted then preempted by higher-priority work: an
                // explicit shed, not a failure.
                Some(Outcome::Shed) => *shed += 1,
                Some(Outcome::Failed(_)) | None => *failed += 1,
            }
        }
        for i in 0..requests {
            if let Some(gap) = arrival.next_gap_s(&mut rng) {
                std::thread::sleep(std::time::Duration::from_secs_f64(gap.min(0.002)));
            }
            let model = &models[i % models.len()];
            let payload = payload_for(&mut rng, model, i);
            let tenant = tenant_for(i);
            match self.submit_as(&tenant, model, payload)? {
                Submission::Enqueued(rx) => {
                    if closed_loop {
                        // One outstanding request: wait before issuing
                        // the next (paper §V-C closed loop).
                        account(
                            rx.recv().ok(),
                            &mut completed,
                            &mut failed,
                            &mut shed,
                            &mut e2e_ms,
                        );
                    } else {
                        pending.push(rx);
                    }
                }
                Submission::Shed => shed += 1,
            }
        }
        for rx in pending {
            account(rx.recv().ok(), &mut completed, &mut failed, &mut shed, &mut e2e_ms);
        }
        Ok(FabricRunReport {
            submitted: requests,
            completed,
            shed,
            failed,
            e2e_ms,
            wall_s: t0.elapsed().as_secs_f64(),
        })
    }

    /// Per-pod report rows (snapshot of each pod's collector), spawn
    /// order, retired pods included — the replica timeline.
    pub fn pod_reports(&self, wall_s: f64) -> Vec<PodReport> {
        self.inner
            .registry
            .load()
            .pods
            .iter()
            .map(|p| {
                let (snap, dispatches) = p.stats();
                PodReport::from_parts(
                    &p.plan,
                    snap,
                    dispatches,
                    wall_s,
                    p.born_ms,
                    *p.retired_ms.lock().unwrap(),
                )
            })
            .collect()
    }

    /// Fleet-aggregate report (merged pod snapshots + shed / dedup /
    /// cache / scale counters).
    pub fn fleet_report(&self, wall_s: f64) -> FleetReport {
        let (snaps, pods, active_pods): (Vec<Snapshot>, usize, usize) = {
            let reg = self.inner.registry.load();
            let snaps = reg.pods.iter().map(|p| p.stats().0).collect();
            let active =
                reg.pods.iter().filter(|p| !p.retired.load(Ordering::Relaxed)).count();
            (snaps, reg.pods.len(), active)
        };
        let merged = Snapshot::merged(snaps);
        FleetReport {
            pods,
            active_pods,
            nodes: self.nodes_spanned().len(),
            requests: merged.requests,
            errors: merged.errors,
            shed: self.shed_total(),
            quota_shed: self.quota_shed_total(),
            preempted: self.preempted_total(),
            deduped: self.dedup_hits(),
            cache: self.cache_stats(),
            scale_ups: self.inner.scaler.as_ref().map_or(0, |s| s.ups.load(Ordering::Relaxed)),
            scale_downs: self
                .inner
                .scaler
                .as_ref()
                .map_or(0, |s| s.downs.load(Ordering::Relaxed)),
            service: boxplot_opt(&merged.service_ms),
            mean_queue_wait_ms: mean_opt(&merged.queue_wait_ms),
            throughput_rps: throughput_rps(merged.requests as usize, wall_s),
            retries: self.retries_total(),
            hedges_won: 0,
            hedges_lost: 0,
            breaker_trips: self.breaker_trips(),
            brownout_ms: 0.0,
            faults_injected: self.faults_injected(),
            last_scale_error: self.last_scale_error(),
        }
    }

    /// Close every pod queue and join the batcher workers, draining all
    /// admitted work to completion, WITHOUT consuming the fabric —
    /// reports stay queryable afterwards, so a caller that needs
    /// post-drain counters (the continuum's graceful whole-site loss
    /// freezes its report row from them) can read before the final
    /// [`shutdown`](Self::shutdown).  Signals the control thread to
    /// stop but does not join it; idempotent.
    pub fn drain(&self) {
        self.inner.stop.store(true, Ordering::Relaxed);
        // Iterate the published snapshot directly — the old path cloned
        // the whole pod vector under the registry lock.
        let snap = self.inner.registry.load();
        for p in &snap.pods {
            p.queue.close();
        }
        for p in &snap.pods {
            for w in p.workers.lock().unwrap().drain(..) {
                let _ = w.join();
            }
        }
    }

    /// Stop the control thread, close every pod queue, drain backlogs,
    /// join workers.
    pub fn shutdown(mut self) {
        self.inner.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.scaler_thread.take() {
            let _ = h.join();
        }
        self.drain();
    }
}

/// Build (but do not start) a pod runtime.
fn new_runtime(
    plan: PodPlan,
    executor: Arc<dyn PodExecutor>,
    cfg: &FabricConfig,
    born_ms: f64,
    lanes: &[LaneConfig],
) -> PodRuntime {
    let controller = cfg.adaptive.then(|| {
        Arc::new(BatchController::new(BatchControlConfig {
            min_batch: cfg.min_batch,
            max_batch: cfg.max_batch,
            slo_p99_ms: cfg.slo_p99_ms,
            ..Default::default()
        }))
    });
    let key = FeedbackStore::key(&plan.aif, &plan.node);
    PodRuntime {
        plan,
        key,
        queue: Arc::new(TenantQueue::new(cfg.queue_capacity, lanes.to_vec())),
        backlog: Arc::new(AtomicU64::new(0)),
        executor: Mutex::new(Some(executor)),
        controller,
        workers: Mutex::new(Vec::new()),
        retired: AtomicBool::new(false),
        final_report: Mutex::new(None),
        born_ms,
        retired_ms: Mutex::new(None),
        breaker: cfg
            .resilience
            .breaker
            .as_ref()
            .map(|b| Mutex::new(CircuitBreaker::new(b.clone()))),
    }
}

/// Lane holding a plurality of the drained batch's items — the batch's
/// dominant tenant.  Ties break toward the lower lane index, so the
/// outcome is deterministic whatever the drain interleaving was.
/// `None` only for an empty batch.
fn dominant_lane(batch: &[Work]) -> Option<usize> {
    let mut counts: BTreeMap<usize, usize> = BTreeMap::new();
    for w in batch {
        *counts.entry(w.lane).or_insert(0) += 1;
    }
    counts
        .into_iter()
        .max_by(|a, b| a.1.cmp(&b.1).then(b.0.cmp(&a.0)))
        .map(|(lane, _)| lane)
}

/// Spawn one pod's batcher workers (free function: worker threads hold
/// an `Arc` of the whole fabric state, which `&self` methods cannot
/// mint on stable Rust).
fn start_workers(inner: &Arc<FabricInner>, pod: &Arc<PodRuntime>) {
    let n = inner.cfg.workers.max(1);
    let handles: Vec<thread::JoinHandle<()>> = (0..n)
        .map(|_| {
            let inner = Arc::clone(inner);
            let pod = Arc::clone(pod);
            thread::spawn(move || inner.worker_loop(&pod))
        })
        .collect();
    pod.workers.lock().unwrap().extend(handles);
}

impl FabricInner {
    /// One batcher worker: drain (adaptive) batches, execute them fused
    /// (or per-item on the reference path), deliver outcomes, feed the
    /// controller.
    fn worker_loop(&self, pod: &Arc<PodRuntime>) {
        let linger = Duration::from_secs_f64(self.cfg.batch_linger_ms.max(0.0) / 1e3);
        let max_batch = self.cfg.max_batch.max(1);
        // One clone up front: the executor slot is emptied only after
        // every worker has been joined, so a running worker always
        // owns a live handle without re-locking per batch.
        let Some(executor) = pod.executor.lock().unwrap().clone() else {
            return;
        };
        // Placeholder swapped into `Work` while a fused batch lends its
        // requests to the executor — one shared empty slice per worker,
        // so staging never allocates.
        let empty: Arc<[f32]> = Vec::new().into();
        loop {
            let take = pod.controller.as_ref().map_or(max_batch, |c| c.drain_size());
            // `None` = closed and drained: the unambiguous shutdown
            // signal (workers block, never spin).
            let Some(batch) = pod.queue.pop_batch_linger(take, linger) else {
                break;
            };
            let drained = batch.len();
            // Per-tenant SLOs: the batch's dominant tenant decides the
            // target the controller backs off against this cycle.  The
            // check is per batch (not hoisted) because `tf2aif apply`
            // can edit SLOs while workers run; the `any_active` counter
            // keeps the no-override fast path a single atomic load.
            let slo_override = if self.lane_slos.any_active() {
                dominant_lane(&batch).and_then(|lane| self.lane_slos.get(lane))
            } else {
                None
            };
            let mut tail_ms = 0.0f64;
            {
                // Every item reaches exactly one terminal verdict here:
                // success delivers (and closes the breaker's failure
                // streak); failure feeds the breaker and either re-routes
                // under the retry policy or delivers `Outcome::Failed`.
                let mut finish = |work: Work, result: Result<Response>| {
                    pod.backlog.fetch_sub(1, Ordering::Relaxed);
                    match result {
                        Ok(resp) => {
                            if let Some(b) = &pod.breaker {
                                b.lock().unwrap().on_success();
                            }
                            self.feedback.observe(&pod.key, resp.service_ms, resp.queue_wait_ms);
                            let e2e = resp.queue_wait_ms + resp.service_ms;
                            if e2e > tail_ms {
                                tail_ms = e2e;
                            }
                            deliver(
                                &self.dedup,
                                self.cache.as_deref(),
                                &work.fan,
                                Outcome::Completed(resp),
                            );
                        }
                        Err(e) => self.fail_or_retry(pod, work, format!("{e:#}")),
                    }
                };
                if self.cfg.fused {
                    // The whole drained batch is ONE device dispatch;
                    // every item stops waiting at dispatch time.  The
                    // requests are lent to the executor and moved back
                    // into their `Work` afterwards so a failed item can
                    // be re-routed whole.
                    let mut reqs = Vec::with_capacity(batch.len());
                    let mut waits = Vec::with_capacity(batch.len());
                    let mut works = Vec::with_capacity(batch.len());
                    for mut work in batch {
                        waits.push(work.enqueued.elapsed().as_secs_f64() * 1e3);
                        reqs.push(std::mem::replace(
                            &mut work.req,
                            Request { id: 0, payload: Arc::clone(&empty) },
                        ));
                        works.push(work);
                    }
                    let results = executor.execute_batch(&reqs, &waits);
                    for ((mut work, req), result) in
                        works.into_iter().zip(reqs).zip(results)
                    {
                        work.req = req;
                        finish(work, result);
                    }
                } else {
                    // Per-item reference path (the bench baseline): one
                    // dispatch per request, and each item's queue wait
                    // is taken at its OWN execution time so the
                    // in-batch serial wait is attributed honestly.
                    for work in batch {
                        let wait_ms = work.enqueued.elapsed().as_secs_f64() * 1e3;
                        let result = executor.execute(&work.req, wait_ms);
                        finish(work, result);
                    }
                }
            }
            if let Some(c) = &pod.controller {
                c.observe_with_slo(
                    drained,
                    pod.queue.len(),
                    tail_ms,
                    self.feedback.get(&pod.key),
                    slo_override,
                );
            }
        }
    }

    /// Router score for a pod: estimated per-request latency (feedback
    /// blended over the cost model) scaled by its backlog — a
    /// least-estimated-work-left policy.
    fn score(&self, pod: &PodRuntime) -> f64 {
        let est = self.feedback.blend(&pod.key, pod.plan.modeled_ms);
        let backlog = pod.backlog.load(Ordering::Relaxed) as f64;
        est * (backlog + 1.0)
    }

    /// Active replicas of `model`, sorted by ascending router score.
    /// Errors for unknown models; an empty vec (every replica retired)
    /// lets the caller shed.
    fn candidates(&self, model: &str) -> Result<Vec<Arc<PodRuntime>>> {
        // Snapshot load: lock-free on the steady state (no scale event
        // since this thread's last submit) — the no-lock-on-submit
        // invariant.
        let reg = self.registry.load();
        let Some(idxs) = reg.by_model.get(model) else {
            let have: Vec<&String> = reg.by_model.keys().collect();
            bail!("fabric serves no model {model:?} (have: {have:?})");
        };
        let mut scored: Vec<(f64, Arc<PodRuntime>)> = idxs
            .iter()
            .map(|&i| &reg.pods[i])
            .filter(|p| !p.retired.load(Ordering::Relaxed))
            .map(|p| (self.score(p), Arc::clone(p)))
            .collect();
        scored.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        Ok(scored.into_iter().map(|(_, p)| p).collect())
    }

    fn submit_as(&self, tenant_id: &str, model: &str, payload: Arc<[f32]>) -> Result<Submission> {
        // Unknown tenants and unknown models are typed errors — config
        // and addressing mistakes, not load to account.
        let tenant = Arc::clone(
            self.tenants
                .get(tenant_id)
                .ok_or_else(|| {
                    anyhow::Error::new(TenancyError::UnknownTenant(tenant_id.to_string()))
                })?,
        );
        let scored = self.candidates(model)?;
        tenant.stats.note_submitted();
        // Offered demand — admitted or not — feeds the predictive
        // autoscaler's arrival-rate estimate: load a fleet sheds is
        // exactly the load a forecast must see.  (The map is empty
        // unless predictive scaling is on.)
        if let Some(rate) = self.arrivals.get(model) {
            rate.observe();
        }

        // Layer 0 — the tenant's own quota, BEFORE any global capacity
        // check: a tenant past its token bucket is shed no matter how
        // idle the fleet is.  Quota sheds are policy, not pressure —
        // they count toward the tenant and the run accounting but never
        // toward the autoscaler's overload signal.
        if !tenant.try_admit_quota() {
            tenant.stats.note_quota_shed();
            self.quota_shed_total.fetch_add(1, Ordering::Relaxed);
            self.shed_total.fetch_add(1, Ordering::Relaxed);
            return Ok(Submission::Shed);
        }

        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = mpsc::channel();
        // Two-tier content addressing: the cheap 64-bit pre-hash is the
        // only digest computed unconditionally; the sha256 confirm runs
        // lazily — and at most once per submission, memoized here —
        // strictly when an index lookup finds an occupied slot.
        let keyed = self.cfg.dedup || self.cache.is_some();
        let key = if keyed { Some(prehash(model, &payload, self.cfg.prehash_mask)) } else { None };
        let mut sha_memo: Option<[u8; 32]> = None;

        // Layer 1 — response cache: a fresh completed response for the
        // same (model, payload) answers immediately, re-stamped with
        // this caller's id.  No queue slot, no execution — and the
        // latency fields are zeroed, because this caller waited for
        // nothing: reporting the leader's historical service time here
        // would poison the e2e percentiles the cache exists to improve.
        if let (Some(cache), Some(k)) = (&self.cache, key) {
            let hit = {
                let mut sha_of = || {
                    *sha_memo.get_or_insert_with(|| {
                        self.sha_confirms.fetch_add(1, Ordering::Relaxed);
                        confirm_sha(model, &payload)
                    })
                };
                cache.get(k, model, &mut sha_of)
            };
            if let Some(resp) = hit {
                tenant.stats.note_admitted();
                tenant.stats.note_completed(0.0);
                let _ = tx.send(Outcome::Completed(Response {
                    id,
                    service_ms: 0.0,
                    real_compute_ms: 0.0,
                    queue_wait_ms: 0.0,
                    ..resp
                }));
                return Ok(Submission::Enqueued(rx));
            }
        }
        let cache_gen = match (&self.cache, &key) {
            (Some(c), Some(_)) => c.generation(model),
            _ => 0,
        };
        let lane = tenant.lane;
        let prio = tenant.spec.priority.rank();
        let routed;
        if self.cfg.dedup {
            let k = key.expect("dedup implies a content key");
            // Layer 2 — in-flight dedup.  The map lock is held across
            // attach/route/register so a completing worker (which also
            // takes it, in `deliver`) cannot unregister an entry between
            // our lookup and our attach — a waiter either rides the
            // in-flight execution or becomes a fresh leader, never
            // neither.  The critical section is small: replica scoring
            // already happened above, so under the lock we only do
            // backlog atomics and at most `replicas` O(1) queue pushes
            // (preemption delivery is deferred until the lock drops —
            // `deliver` re-takes it).  Buckets hold every in-flight
            // leader sharing a pre-hash; attaching requires a sha256
            // confirm on BOTH sides, so a 64-bit collision can never
            // collapse distinct payloads onto one execution.
            let mut map = self.dedup.lock().unwrap();
            if let Some(bucket) = map.get(&k) {
                let attach = bucket.iter().find(|f| {
                    f.model == model && {
                        let sha = *sha_memo.get_or_insert_with(|| {
                            self.sha_confirms.fetch_add(1, Ordering::Relaxed);
                            confirm_sha(model, &payload)
                        });
                        f.confirm(Some(&self.sha_confirms)) == sha
                    }
                });
                if let Some(entry) = attach {
                    entry.waiters.lock().unwrap().push((id, Arc::clone(&tenant), tx));
                    self.dedup_hits.fetch_add(1, Ordering::Relaxed);
                    tenant.stats.note_admitted();
                    return Ok(Submission::Enqueued(rx));
                }
            }
            let fan = Arc::new(Fanout {
                key: Some(k),
                sha: OnceLock::new(),
                model: model.to_string(),
                payload: Arc::clone(&payload),
                cache_gen,
                waiters: Mutex::new(vec![(id, Arc::clone(&tenant), tx)]),
            });
            if let Some(s) = sha_memo {
                let _ = fan.sha.set(s);
            }
            let work = Work {
                req: Request { id, payload: Arc::clone(&payload) },
                enqueued: Instant::now(),
                fan: Arc::clone(&fan),
                lane,
                prio,
                attempt: 0,
            };
            routed = self.try_route(&scored, work);
            if routed.admitted {
                map.entry(k).or_default().push(fan);
            }
        } else {
            let fan = Arc::new(Fanout {
                key,
                sha: OnceLock::new(),
                model: model.to_string(),
                payload: Arc::clone(&payload),
                cache_gen,
                waiters: Mutex::new(vec![(id, Arc::clone(&tenant), tx)]),
            });
            if let Some(s) = sha_memo {
                let _ = fan.sha.set(s);
            }
            let work = Work {
                req: Request { id, payload },
                enqueued: Instant::now(),
                fan,
                lane,
                prio,
                attempt: 0,
            };
            routed = self.try_route(&scored, work);
        }
        // Deliver preemption sheds OUTSIDE the dedup lock: each evicted
        // entry may be a dedup leader whose unregistration (`deliver`)
        // takes the same lock.  `deliver` reports how many callers it
        // reached (the leader plus any dedup'd followers), so the fleet
        // counters stay per-caller consistent with the per-tenant
        // accounting and the run invariant `completed + failed + shed ==
        // submitted`.
        for evicted in routed.evicted {
            let callers =
                deliver(&self.dedup, self.cache.as_deref(), &evicted.fan, Outcome::Shed);
            self.note_preemption(&evicted, callers);
        }
        if routed.admitted {
            tenant.stats.note_admitted();
            return Ok(Submission::Enqueued(rx));
        }
        tenant.stats.note_capacity_shed();
        self.shed_total.fetch_add(1, Ordering::Relaxed);
        self.note_shed(model, 1);
        self.add_pressure(model, prio);
        Ok(Submission::Shed)
    }

    /// Try each scored replica in order.  `admitted` is true when a
    /// queue took the work — possibly by preempting strictly-lower-
    /// priority queued entries, which come back in `evicted` for the
    /// caller to shed explicitly.  Not admitted means every queue was at
    /// the admission bound for this priority (or closed by a concurrent
    /// retire — closed queues bounce pushes).
    fn try_route(&self, scored: &[Arc<PodRuntime>], mut work: Work) -> RouteOutcome {
        let (lane, prio) = (work.lane, work.prio);
        let now_ms = self.epoch.elapsed().as_secs_f64() * 1e3;
        for pod in scored {
            // An open circuit breaker removes the pod from rotation;
            // half-open lets a bounded number of probes through and the
            // probes' verdicts decide recovery.
            if let Some(b) = &pod.breaker {
                if !b.lock().unwrap().allow(now_ms) {
                    continue;
                }
            }
            pod.backlog.fetch_add(1, Ordering::Relaxed);
            match pod.queue.push(lane, prio, work) {
                Push::Admitted(evicted) => {
                    // Each evicted entry held a backlog slot on THIS pod.
                    for _ in &evicted {
                        pod.backlog.fetch_sub(1, Ordering::Relaxed);
                    }
                    return RouteOutcome { admitted: true, evicted };
                }
                Push::Rejected(returned) => {
                    pod.backlog.fetch_sub(1, Ordering::Relaxed);
                    work = returned;
                }
            }
        }
        RouteOutcome { admitted: false, evicted: Vec::new() }
    }

    /// Account one preempted queue entry that affected `callers` waiters
    /// (the leader plus any dedup'd followers — `deliver`'s count, so
    /// fleet totals match the per-tenant columns and every affected
    /// caller's `Outcome::Shed` is mirrored in `shed_total`).  Pressure
    /// is charged once per evicted entry: one *execution's* worth of
    /// capacity was lost, however many callers had collapsed onto it.
    fn note_preemption(&self, work: &Work, callers: u64) {
        self.preempted_total.fetch_add(callers, Ordering::Relaxed);
        self.shed_total.fetch_add(callers, Ordering::Relaxed);
        self.note_shed(&work.fan.model, callers);
        self.add_pressure(&work.fan.model, work.prio);
    }

    /// Fold `n` sheds into the model's atomic counter.  The map covers
    /// every routable model (built at spawn), so a miss can only mean
    /// the caller fabricated a model name — and those error out in
    /// `candidates` long before any accounting runs.
    fn note_shed(&self, model: &str, n: u64) {
        if let Some(c) = self.model_stats.get(model) {
            c.shed.fetch_add(n, Ordering::Relaxed);
        }
    }

    /// Fold one capacity shed / preemption into the model's
    /// priority-weighted pressure (the autoscaler's overload signal).
    /// The increment `1 + prio` is integral, so the atomic counter
    /// carries the f64 semantics of the old mutex map exactly.
    fn add_pressure(&self, model: &str, prio: u8) {
        if let Some(c) = self.model_stats.get(model) {
            c.pressure.fetch_add(1 + prio as u64, Ordering::Relaxed);
        }
    }

    /// One executor failure's terminal path: feed `pod`'s breaker, then
    /// — while the retry policy allows (attempt bound + deadline against
    /// the original enqueue) — re-route the work to the current best
    /// replica set.  When retries are off, exhausted, or no replica
    /// admits the work, every waiter gets a terminal
    /// [`Outcome::Failed`]; nothing is dropped silently and nothing is
    /// delivered twice.
    fn fail_or_retry(&self, pod: &PodRuntime, work: Work, error: String) {
        if let Some(b) = &pod.breaker {
            b.lock().unwrap().on_failure(self.epoch.elapsed().as_secs_f64() * 1e3);
        }
        let retry_ok = self.cfg.resilience.retry.as_ref().map_or(false, |rp| {
            let waited_ms = work.enqueued.elapsed().as_secs_f64() * 1e3;
            rp.may_retry(work.attempt + 1, 0.0, waited_ms)
        });
        let fan = Arc::clone(&work.fan);
        if retry_ok {
            let mut work = work;
            work.attempt += 1;
            self.retries_total.fetch_add(1, Ordering::Relaxed);
            if let Ok(scored) = self.candidates(&fan.model) {
                let routed = self.try_route(&scored, work);
                for evicted in routed.evicted {
                    let callers = deliver(
                        &self.dedup,
                        self.cache.as_deref(),
                        &evicted.fan,
                        Outcome::Shed,
                    );
                    self.note_preemption(&evicted, callers);
                }
                if routed.admitted {
                    return;
                }
            }
        }
        deliver(&self.dedup, self.cache.as_deref(), &fan, Outcome::Failed(error));
    }

    /// Crash one pod: retire it immediately, trip its breaker, seize its
    /// queued backlog, and give every seized item a terminal path —
    /// re-routed to surviving replicas under the retry policy, or a
    /// terminal [`Outcome::Failed`] when none admits it.  Dedup'd
    /// followers riding a seized leader get the leader's verdict; nobody
    /// hangs.  Returns the number of queued items seized.
    fn crash_pod(&self, pod: &Arc<PodRuntime>) -> usize {
        self.faults_injected.fetch_add(1, Ordering::Relaxed);
        pod.retired.store(true, Ordering::Relaxed);
        let now_ms = self.epoch.elapsed().as_secs_f64() * 1e3;
        *pod.retired_ms.lock().unwrap() = Some(now_ms);
        if let Some(b) = &pod.breaker {
            // A crash is a failure burst: open the breaker now so the
            // router's view and the crash agree.
            let mut b = b.lock().unwrap();
            for _ in 0..16 {
                if !b.is_closed() {
                    break;
                }
                b.on_failure(now_ms);
            }
        }
        let _ = self.cluster.lock().unwrap().terminate(pod.plan.pod_id);
        // `drain_all` closes the queue and seizes whatever was admitted
        // but not yet drained by a worker; items a worker already holds
        // finish executing and deliver normally (the threaded path kills
        // the queue, not the in-flight dispatch — the virtual-time
        // engine models the mid-batch kill exactly).
        let orphans = pod.queue.drain_all();
        let seized = orphans.len();
        for work in orphans {
            pod.backlog.fetch_sub(1, Ordering::Relaxed);
            self.fail_or_retry(pod, work, format!("pod crashed: {}@{}", pod.plan.aif, pod.plan.node));
        }
        seized
    }
}

/// Result of routing one admitted-or-not submission across replicas.
struct RouteOutcome {
    admitted: bool,
    /// Lower-priority queue entries preempted to admit the work.
    evicted: Vec<Work>,
}

/// Forecast level (per-replica concurrency) below which predictive
/// demand reads as idle — see the idle gate in [`autoscale_tick`].
const FORECAST_IDLE_EPS: f64 = 0.01;

/// Forecast level at which predictive demand reads as overloaded.  The
/// forecast is per-replica *concurrency* (Little's law: offered rate ×
/// service time / replicas) — at 1.0 the offered load exactly saturates
/// the active replicas and any excess MUST become queue depth, so the
/// predictive path scales at the saturation boundary instead of
/// borrowing `scale_up_backlog` (a queue-depth threshold in different
/// units, which would defer predictive scale-ups until the backlog it
/// exists to prevent was already inevitable).
const FORECAST_SATURATION: f64 = 1.0;

/// Per-tick retention of the windowed shed-pressure signal: what a tick
/// does not consume, the next tick halves.  With the smallest possible
/// shed weighing 1.0, a lone burst decays below [`PRESSURE_FLOOR`]
/// (and snaps to exactly zero — the idle gate needs a true zero) within
/// a handful of quiet ticks.
const PRESSURE_DECAY: f64 = 0.5;

/// Below this the windowed pressure snaps to 0.0: the geometric decay
/// alone never reaches zero, and the idle gate requires it.
const PRESSURE_FLOOR: f64 = 0.125;

/// Windowed pressure at or above which a model reads as overloaded (one
/// fresh best-effort shed is enough — same sensitivity as the old
/// raw-delta trigger, but it now expires).
const PRESSURE_OVERLOAD: f64 = 1.0;

/// One autoscaler step: classify every model from mean backlog per
/// active replica and shed deltas, debounce through the hysteresis
/// gate, then act within min/max (and per-platform) bounds.  A free
/// function because scale-ups spawn worker threads that need an `Arc`
/// of the fabric state.
fn autoscale_tick(inner: &Arc<FabricInner>) {
    let Some(sc) = &inner.scaler else { return };
    inner.reap_retired();
    let a = sc.auto.lock().unwrap().clone();
    let models: Vec<String> = inner.registry.load().by_model.keys().cloned().collect();
    for model in models {
        let (active, backlog_sum, est_sum_ms) = {
            // Re-load per model: a scale-up for the previous model
            // published a fresh snapshot this tick should see.
            let reg = inner.registry.load();
            let mut active = 0usize;
            let mut backlog = 0u64;
            let mut est_ms = 0.0f64;
            if let Some(idxs) = reg.by_model.get(&model) {
                for &i in idxs {
                    let p = &reg.pods[i];
                    if !p.retired.load(Ordering::Relaxed) {
                        active += 1;
                        backlog += p.backlog.load(Ordering::Relaxed);
                        est_ms += inner.feedback.blend(&p.key, p.plan.modeled_ms);
                    }
                }
            }
            (active, backlog, est_ms)
        };
        if active == 0 {
            continue;
        }
        // Predictive signal — Little's law over the offered-arrival
        // EWMA: the per-replica concurrency the current demand WILL
        // sustain (rate × estimated service time / replicas), compared
        // against the same thresholds the measured backlog is.  Zero
        // when predictive scaling is off or the estimator is cold, so
        // the reactive path below is always the fallback.
        let forecast = if a.predictive {
            inner
                .arrivals
                .get(&model)
                .and_then(|r| r.rate_rps())
                .map_or(0.0, |rate| {
                    let mean_est_s = est_sum_ms / active as f64 / 1e3;
                    rate * mean_est_s / active as f64
                })
        } else {
            0.0
        };
        // Priority-weighted shed pressure (capacity sheds + preemptions,
        // each scaled by 1 + priority rank): losing protected traffic
        // pushes scale-up harder than losing best-effort traffic, and
        // per-tenant quota sheds never register here at all.
        let pressure_now = inner
            .model_stats
            .get(&model)
            .map_or(0.0, |c| c.pressure.load(Ordering::Relaxed) as f64);
        let mut pm = sc.per_model.lock().unwrap();
        let st = pm.entry(model.clone()).or_default();
        let pressure_delta = (pressure_now - st.last_pressure).max(0.0);
        st.last_pressure = pressure_now;
        // Time-windowed, not cumulative: fresh sheds fold in, old sheds
        // decay out, so overload classification tracks *recent* loss and
        // a storm burst stops reading as overload shortly after the
        // storm ends.  Decay runs even during cooldown.
        st.windowed_pressure = st.windowed_pressure * PRESSURE_DECAY + pressure_delta;
        if st.windowed_pressure < PRESSURE_FLOOR {
            st.windowed_pressure = 0.0;
        }
        let windowed = st.windowed_pressure;
        if st.cooldown > 0 {
            st.cooldown -= 1;
            continue;
        }
        let mean_backlog = backlog_sum as f64 / active as f64;
        let overloaded = mean_backlog >= a.scale_up_backlog
            || windowed >= PRESSURE_OVERLOAD
            || forecast >= FORECAST_SATURATION;
        // The forecast is continuous (unlike the integer backlog, it
        // never hits an exact 0 while any trickle of demand flows), so
        // the idle gate grants it a small floor: a forecast occupying
        // under 1% of one replica must not pin a
        // `scale_down_backlog == 0` fleet at its high-water mark.
        let idle = !overloaded
            && mean_backlog <= a.scale_down_backlog
            && windowed == 0.0
            && forecast <= FORECAST_IDLE_EPS;
        match st.gate.decide(overloaded, idle, a.hold_ticks) {
            Some(ScaleDirection::Up) if active < a.max_replicas => {
                let trigger = if windowed >= PRESSURE_OVERLOAD {
                    format!("shed pressure {windowed:.1} (windowed)")
                } else if mean_backlog >= a.scale_up_backlog {
                    format!("backlog {mean_backlog:.1}/replica")
                } else {
                    format!("forecast {forecast:.1}/replica")
                };
                if scale_up(inner, &model, sc, active, &trigger) {
                    st.cooldown = a.cooldown_ticks;
                }
            }
            Some(ScaleDirection::Down) if active > a.min_replicas.max(1) => {
                let trigger = format!("backlog {mean_backlog:.1}/replica");
                if inner.scale_down(&model, sc, active, &trigger) {
                    st.cooldown = a.cooldown_ticks;
                }
            }
            _ => {}
        }
    }
}

/// Bind + spawn one more replica of `model`, placed by the scaler's
/// feedback-blended backend ranking, on a node not already hosting the
/// model and a platform still under its per-model ceiling.
fn scale_up(
    inner: &Arc<FabricInner>,
    model: &str,
    sc: &ScalerState,
    active: usize,
    trigger: &str,
) -> bool {
    let (nodes_used, plat_counts) = {
        let reg = inner.registry.load();
        let mut nodes: BTreeSet<String> = BTreeSet::new();
        let mut plats: BTreeMap<&'static str, usize> = BTreeMap::new();
        if let Some(idxs) = reg.by_model.get(model) {
            for &i in idxs {
                let p = &reg.pods[i];
                if p.retired.load(Ordering::Relaxed) {
                    continue;
                }
                nodes.insert(p.plan.node.clone());
                if let Some(plat) = platform::get(&p.plan.variant) {
                    *plats.entry(plat.name).or_insert(0) += 1;
                }
            }
        }
        (nodes, plats)
    };
    // Rank under a short lock; each candidate's bind re-validates
    // capacity, so a slightly stale ranking only costs a failed bind.
    let ranked = {
        let cluster = inner.cluster.lock().unwrap();
        sc.backend.rank(model, &cluster)
    };
    let Ok(ranked) = ranked else {
        return false;
    };
    for d in ranked {
        if nodes_used.contains(&d.node) {
            continue;
        }
        let Some(plat) = platform::get(&d.variant) else { continue };
        if plat_counts.get(plat.name).copied().unwrap_or(0) >= plat.max_replicas_per_model() {
            continue;
        }
        // A refcount bump — scale-ups never clone model weight bytes.
        let Some(artifact) = sc
            .backend
            .variants_of(model)
            .into_iter()
            .find(|a| a.manifest.variant == d.variant)
            .cloned()
        else {
            continue;
        };
        let mem = Backend::pod_memory_gb(&artifact);
        let bound = {
            let mut cluster = inner.cluster.lock().unwrap();
            cluster.bind(&d.aif, &d.variant, &d.node, mem)
        };
        let Ok(pod_id) = bound else {
            continue;
        };
        let plan = PodPlan {
            aif: d.aif.clone(),
            model: model.to_string(),
            variant: d.variant.clone(),
            node: d.node.clone(),
            pod_id,
            modeled_ms: d.modeled_ms,
        };
        // The slot is bound and the cluster lock released: for a real
        // pod the factory is a PJRT compile taking seconds, and
        // nothing else (router, `with_cluster`, other models'
        // decisions) should stall behind it.
        let executor = match (inner.factory)(&plan, &artifact) {
            Ok(e) => e,
            Err(e) => {
                // Unwind this bind, remember why, and try the next
                // ranked placement — one broken node must not wedge
                // the autoscaler.
                let _ = inner.cluster.lock().unwrap().terminate(pod_id);
                *sc.last_spawn_error.lock().unwrap() =
                    Some(format!("{}@{}: {e:#}", plan.aif, plan.node));
                continue;
            }
        };
        let born_ms = inner.epoch.elapsed().as_secs_f64() * 1e3;
        let pod = Arc::new(new_runtime(plan, executor, &inner.cfg, born_ms, &inner.lanes));
        start_workers(inner, &pod);
        {
            // Copy-on-write publish: build the successor snapshot off
            // to the side and swap it in — concurrent submits keep
            // routing on the old snapshot, lock-free, the whole time.
            let _guard = inner.registry_write.lock().unwrap();
            let cur = inner.registry.load();
            let mut pods = cur.pods.clone();
            let mut by_model = cur.by_model.clone();
            let idx = pods.len();
            pods.push(Arc::clone(&pod));
            by_model.entry(model.to_string()).or_default().push(idx);
            inner.registry.publish(RegistrySnapshot { pods, by_model });
        }
        sc.ups.fetch_add(1, Ordering::Relaxed);
        sc.events.lock().unwrap().push(ScaleEvent {
            at_ms: born_ms,
            model: model.to_string(),
            direction: ScaleDirection::Up,
            aif: pod.plan.aif.clone(),
            node: pod.plan.node.clone(),
            replicas_after: active + 1,
            trigger: trigger.to_string(),
        });
        return true;
    }
    false
}

impl FabricInner {
    /// Reap retired pods whose workers have finished draining: join
    /// the threads, freeze the pod's report, and release the executor —
    /// for a real pod that drops the compiled model and its pinned
    /// weights, which is the memory a scale-down exists to reclaim.
    /// Runs at the top of every autoscaler tick; pods still draining
    /// are left for a later tick (never blocks).
    fn reap_retired(&self) {
        // Reaping frees the executor in place (the snapshot keeps the
        // pod's row for reports); no structural change, so no republish.
        let snap = self.registry.load();
        for pod in snap.pods.iter().filter(|p| p.retired.load(Ordering::Relaxed)) {
            let mut workers = pod.workers.lock().unwrap();
            if workers.is_empty() {
                continue; // already reaped (or shutdown got there first)
            }
            if !workers.iter().all(|w| w.is_finished()) {
                continue; // still draining admitted work
            }
            for w in workers.drain(..) {
                let _ = w.join();
            }
            drop(workers);
            let mut slot = pod.executor.lock().unwrap();
            if let Some(e) = slot.as_ref() {
                *pod.final_report.lock().unwrap() =
                    Some((e.collector().snapshot(), e.dispatches()));
            }
            *slot = None;
        }
    }

    /// Retire the active replica of `model` with the worst estimated
    /// latency (the inverse of placement ranking).  Graceful: the
    /// router stops seeing the pod immediately (closed queues bounce
    /// pushes), its workers drain everything already admitted and exit,
    /// and the cluster releases the slot and memory.
    fn scale_down(
        &self,
        model: &str,
        sc: &ScalerState,
        active: usize,
        trigger: &str,
    ) -> bool {
        let victim: Option<Arc<PodRuntime>> = {
            let reg = self.registry.load();
            let mut worst: Option<(f64, Arc<PodRuntime>)> = None;
            if let Some(idxs) = reg.by_model.get(model) {
                for &i in idxs {
                    let p = &reg.pods[i];
                    if p.retired.load(Ordering::Relaxed) {
                        continue;
                    }
                    let est = self.feedback.blend(&p.key, p.plan.modeled_ms);
                    if worst.as_ref().map_or(true, |(w, _)| est > *w) {
                        worst = Some((est, Arc::clone(p)));
                    }
                }
            }
            worst.map(|(_, p)| p)
        };
        let Some(pod) = victim else { return false };
        pod.retired.store(true, Ordering::Relaxed);
        pod.queue.close();
        let at_ms = self.epoch.elapsed().as_secs_f64() * 1e3;
        *pod.retired_ms.lock().unwrap() = Some(at_ms);
        let _ = self.cluster.lock().unwrap().terminate(pod.plan.pod_id);
        sc.downs.fetch_add(1, Ordering::Relaxed);
        sc.events.lock().unwrap().push(ScaleEvent {
            at_ms,
            model: model.to_string(),
            direction: ScaleDirection::Down,
            aif: pod.plan.aif.clone(),
            node: pod.plan.node.clone(),
            replicas_after: active - 1,
            trigger: trigger.to_string(),
        });
        true
    }
}

fn boxplot_opt(s: &Series) -> Option<Boxplot> {
    if s.is_empty() {
        None
    } else {
        Some(s.clone().boxplot())
    }
}

fn mean_opt(s: &Series) -> f64 {
    if s.is_empty() {
        0.0
    } else {
        s.mean()
    }
}

/// Result of one [`Fabric::run`] drive.
#[derive(Debug, Clone)]
pub struct FabricRunReport {
    /// Requests submitted to the router.
    pub submitted: usize,
    /// Requests served to completion.
    pub completed: usize,
    /// Requests shed at the admission bound.
    pub shed: usize,
    /// Requests that reached a pod but failed there.
    pub failed: usize,
    /// End-to-end (queue wait + service) latencies of completed
    /// requests, ms.
    pub e2e_ms: Series,
    /// Wall-clock of the whole drive, seconds.
    pub wall_s: f64,
}

impl FabricRunReport {
    /// Completed-request throughput over the drive wall-clock.
    pub fn throughput_rps(&self) -> f64 {
        throughput_rps(self.completed, self.wall_s)
    }

    /// Every submitted request must be accounted: completed, failed, or
    /// explicitly shed.
    pub fn fully_accounted(&self) -> bool {
        self.completed + self.failed + self.shed == self.submitted
    }
}

/// One pod's row in the fabric report.
#[derive(Debug, Clone)]
pub struct PodReport {
    /// AIF identity (`model_variant`).
    pub aif: String,
    /// Platform variant.
    pub variant: String,
    /// Hosting node.
    pub node: String,
    /// Requests served.
    pub requests: u64,
    /// Executor errors.
    pub errors: u64,
    /// Device dispatches performed (fused batches count once).
    pub dispatches: u64,
    /// Average fused batch size (`requests / dispatches`; 0 when idle) —
    /// the amortization proof for production runs.
    pub avg_batch: f64,
    /// Service-latency five-number summary (None when idle).
    pub service: Option<Boxplot>,
    /// Mean time requests spent queued, ms.
    pub mean_queue_wait_ms: f64,
    /// Served throughput over the drive wall-clock.
    pub throughput_rps: f64,
    /// Milliseconds after the fabric epoch this pod spawned (0 for
    /// initial placements).
    pub born_ms: f64,
    /// Milliseconds after the fabric epoch the autoscaler retired this
    /// pod (None while active).
    pub retired_ms: Option<f64>,
}

impl PodReport {
    fn from_parts(
        plan: &PodPlan,
        snap: Snapshot,
        dispatches: u64,
        wall_s: f64,
        born_ms: f64,
        retired_ms: Option<f64>,
    ) -> PodReport {
        PodReport {
            aif: plan.aif.clone(),
            variant: plan.variant.clone(),
            node: plan.node.clone(),
            requests: snap.requests,
            errors: snap.errors,
            dispatches,
            avg_batch: if dispatches > 0 { snap.requests as f64 / dispatches as f64 } else { 0.0 },
            service: boxplot_opt(&snap.service_ms),
            mean_queue_wait_ms: mean_opt(&snap.queue_wait_ms),
            throughput_rps: throughput_rps(snap.requests as usize, wall_s),
            born_ms,
            retired_ms,
        }
    }
}

/// Fleet-aggregate row in the fabric report.
#[derive(Debug, Clone)]
pub struct FleetReport {
    /// Pods spawned over the fabric's lifetime (retired included).
    pub pods: usize,
    /// Pods currently active.
    pub active_pods: usize,
    /// Distinct nodes hosting active pods.
    pub nodes: usize,
    /// Requests served fleet-wide.
    pub requests: u64,
    /// Executor errors fleet-wide.
    pub errors: u64,
    /// Every shed (quota + capacity + preemptions).
    pub shed: u64,
    /// Of `shed`: submissions rejected by per-tenant token-bucket
    /// quotas (policy, not capacity — excluded from autoscaler
    /// pressure).
    pub quota_shed: u64,
    /// Of `shed`: callers whose admitted request was evicted by
    /// higher-priority work (dedup'd followers each count, matching the
    /// per-tenant columns).
    pub preempted: u64,
    /// Submissions answered by in-flight dedup (no fresh execution).
    pub deduped: u64,
    /// Response-cache counters (None when the cache is off).
    pub cache: Option<CacheStats>,
    /// Replicas the autoscaler added.
    pub scale_ups: u64,
    /// Replicas the autoscaler retired.
    pub scale_downs: u64,
    /// Merged service-latency summary (None when idle).
    pub service: Option<Boxplot>,
    /// Mean queue wait fleet-wide, ms.
    pub mean_queue_wait_ms: f64,
    /// Fleet throughput over the drive wall-clock.
    pub throughput_rps: f64,
    /// Executor-failure retries re-routed under the resilience policy.
    pub retries: u64,
    /// Hedged duplicates whose copy finished first (virtual-time path;
    /// the threaded router does not hedge, so 0 there).
    pub hedges_won: u64,
    /// Hedged duplicates cancelled or beaten by the primary
    /// (virtual-time path; 0 on the threaded router).
    pub hedges_lost: u64,
    /// Circuit-breaker trips (closed→open transitions) across all pods.
    pub breaker_trips: u64,
    /// Total brownout-degraded milliseconds (virtual-time path; 0 on
    /// the threaded router).
    pub brownout_ms: f64,
    /// Faults injected (pod crashes on the threaded path).
    pub faults_injected: u64,
    /// Most recent autoscaler pod-spawn failure — surfaced so drill
    /// runs show *why* capacity moved (or failed to).
    pub last_scale_error: Option<String>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::paper_testbed;

    fn sim_fabric(cfg: &FabricConfig, gate: Option<Arc<Gate>>) -> Fabric {
        let backend = Backend::new(sim::synthetic_catalog(), Policy::MinLatency);
        let mut cluster = Cluster::new(paper_testbed());
        cluster.apply_kube_api_extension();
        Fabric::place_sim(&backend, cluster, cfg, gate).unwrap()
    }

    #[test]
    fn placement_shards_models_across_nodes() {
        let cfg = FabricConfig { time_scale: 0.0, ..Default::default() };
        let fabric = sim_fabric(&cfg, None);
        assert_eq!(fabric.models().len(), 4, "all Table III models placed");
        assert!(
            fabric.nodes_spanned().len() >= 3,
            "fleet must span the Table II testbed, got {:?}",
            fabric.nodes_spanned()
        );
        for model in fabric.models() {
            let nodes: BTreeSet<_> = fabric
                .plans()
                .into_iter()
                .filter(|p| p.model == model)
                .map(|p| p.node)
                .collect();
            assert!(!nodes.is_empty(), "{model} unplaced");
            assert!(nodes.len() <= cfg.replicas_per_model);
        }
        fabric.shutdown();
    }

    #[test]
    fn replicas_land_on_distinct_nodes() {
        let cfg = FabricConfig { time_scale: 0.0, ..Default::default() };
        let fabric = sim_fabric(&cfg, None);
        for model in fabric.models() {
            let nodes: Vec<_> = fabric
                .plans()
                .into_iter()
                .filter(|p| p.model == model)
                .map(|p| p.node)
                .collect();
            let distinct: BTreeSet<_> = nodes.iter().cloned().collect();
            assert_eq!(nodes.len(), distinct.len(), "{model}: replica nodes must differ");
        }
        fabric.shutdown();
    }

    #[test]
    fn closed_loop_run_completes_everything() {
        let cfg = FabricConfig { time_scale: 0.0, ..Default::default() };
        let fabric = sim_fabric(&cfg, None);
        let report = fabric.run(40, Arrival::ClosedLoop, 11).unwrap();
        assert!(report.fully_accounted());
        assert_eq!(report.failed, 0);
        assert_eq!(report.completed + report.shed, 40);
        assert!(report.completed > 0);
        let fleet = fabric.fleet_report(report.wall_s);
        assert_eq!(fleet.requests, report.completed as u64);
        assert_eq!(fleet.shed as usize, report.shed);
        assert_eq!(fleet.active_pods, fleet.pods, "nothing retired without autoscaling");
        fabric.shutdown();
    }

    #[test]
    fn feedback_store_learns_from_traffic() {
        let cfg = FabricConfig { time_scale: 0.0, ..Default::default() };
        let fabric = sim_fabric(&cfg, None);
        fabric.run(60, Arrival::ClosedLoop, 3).unwrap();
        let store = fabric.feedback();
        assert!(
            !store.all().is_empty(),
            "completed traffic must produce feedback observations"
        );
        for (key, fb) in store.all() {
            assert!(fb.ewma_service_ms > 0.0, "{key}");
            assert!(fb.ewma_queue_wait_ms >= 0.0, "{key}");
            assert!(fb.observations > 0);
        }
        fabric.shutdown();
    }

    #[test]
    fn unknown_model_is_an_error_not_a_silent_drop() {
        let cfg = FabricConfig { time_scale: 0.0, ..Default::default() };
        let fabric = sim_fabric(&cfg, None);
        assert!(fabric.submit("not-a-model", vec![]).is_err());
        fabric.shutdown();
    }

    #[test]
    fn unknown_tenant_is_a_typed_error_not_a_panic() {
        let cfg = FabricConfig { time_scale: 0.0, ..Default::default() };
        let fabric = sim_fabric(&cfg, None);
        let err = fabric.submit_as("nobody", "lenet", vec![1.0; 4]).unwrap_err();
        assert!(
            matches!(
                err.downcast_ref::<TenancyError>(),
                Some(TenancyError::UnknownTenant(id)) if id == "nobody"
            ),
            "expected a typed UnknownTenant error, got: {err:#}"
        );
        // The default tenant still serves.
        assert!(matches!(
            fabric.submit_as(DEFAULT_TENANT, "lenet", vec![1.0; 4]).unwrap(),
            Submission::Enqueued(_)
        ));
        fabric.shutdown();
    }

    #[test]
    fn zero_quota_tenant_config_is_rejected_at_spawn() {
        let mut spec = TenantSpec::new("broken");
        spec.rate_rps = Some(0.0);
        let cfg =
            FabricConfig { time_scale: 0.0, tenants: vec![spec], ..Default::default() };
        let backend = Backend::new(sim::synthetic_catalog(), Policy::MinLatency);
        let mut cluster = Cluster::new(paper_testbed());
        cluster.apply_kube_api_extension();
        let err = Fabric::place_sim(&backend, cluster, &cfg, None).unwrap_err();
        assert!(
            matches!(
                err.downcast_ref::<TenancyError>(),
                Some(TenancyError::ZeroQuota(id)) if id == "broken"
            ),
            "expected a typed ZeroQuota error, got: {err:#}"
        );
    }

    #[test]
    fn dedup_entry_is_removed_after_completion() {
        // Without a gate the execution completes quickly; afterwards the
        // same payload must start a fresh execution (memoization is
        // in-flight only — no cache configured here).
        let cfg = FabricConfig { time_scale: 0.0, ..Default::default() };
        let fabric = sim_fabric(&cfg, None);
        for round in 0..3 {
            match fabric.submit("lenet", vec![1.0; 32]).unwrap() {
                Submission::Enqueued(rx) => {
                    assert!(matches!(rx.recv().unwrap(), Outcome::Completed(_)), "{round}");
                }
                Submission::Shed => panic!("no load — must admit"),
            }
        }
        // Sequential identical submissions never overlapped → no hits,
        // three real executions.
        assert_eq!(fabric.dedup_hits(), 0);
        let served: u64 = fabric.pod_reports(1.0).iter().map(|r| r.requests).sum();
        assert_eq!(served, 3);
        fabric.shutdown();
    }

    #[test]
    fn response_cache_serves_repeats_without_reexecution() {
        let cfg = FabricConfig {
            time_scale: 0.0,
            cache_capacity: 32,
            cache_ttl_ms: 60_000,
            ..Default::default()
        };
        let fabric = sim_fabric(&cfg, None);
        let payload = vec![0.25; 64];
        for round in 0u64..3 {
            match fabric.submit("lenet", payload.clone()).unwrap() {
                Submission::Enqueued(rx) => match rx.recv().unwrap() {
                    Outcome::Completed(resp) => assert_eq!(
                        resp.id, round,
                        "cached responses are re-stamped per caller"
                    ),
                    Outcome::Failed(e) => panic!("{e}"),
                },
                Submission::Shed => panic!("idle fabric must admit"),
            }
        }
        let served: u64 = fabric.pod_reports(1.0).iter().map(|r| r.requests).sum();
        assert_eq!(served, 1, "rounds 2 and 3 must be cache hits, not executions");
        let stats = fabric.cache_stats().unwrap();
        assert_eq!((stats.hits, stats.misses), (2, 1));
        let fleet = fabric.fleet_report(1.0);
        assert_eq!(fleet.cache.unwrap().hits, 2, "cache counters surface in the fleet report");
        fabric.shutdown();
    }

    #[test]
    fn pod_reports_prove_amortization_via_dispatch_counts() {
        let cfg = FabricConfig { time_scale: 0.0, ..Default::default() };
        let fabric = sim_fabric(&cfg, None);
        let run = fabric.run(80, Arrival::ClosedLoop, 17).unwrap();
        assert!(run.completed > 0);
        let reports = fabric.pod_reports(run.wall_s);
        let served: u64 = reports.iter().map(|r| r.requests).sum();
        let dispatches: u64 = reports.iter().map(|r| r.dispatches).sum();
        assert!(dispatches > 0 && dispatches <= served, "{dispatches} vs {served}");
        for r in reports.iter().filter(|r| r.requests > 0) {
            assert!(r.avg_batch >= 1.0, "{}: avg batch {}", r.aif, r.avg_batch);
            assert!(r.retired_ms.is_none(), "nothing retires without autoscaling");
        }
        fabric.shutdown();
    }

    #[test]
    fn shed_pressure_decays_after_overload_ends() {
        let cfg = FabricConfig {
            time_scale: 0.0,
            autoscale: Some(AutoscaleConfig { interval_ms: 0, ..Default::default() }),
            ..Default::default()
        };
        let fabric = sim_fabric(&cfg, None);
        let model = fabric.models()[0].clone();
        // A storm burst: 8 priority-2 sheds land between two ticks.
        for _ in 0..8 {
            fabric.inner.add_pressure(&model, 2);
        }
        fabric.autoscale_tick();
        let read = |f: &Fabric| {
            let sc = f.inner.scaler.as_ref().unwrap();
            let pm = sc.per_model.lock().unwrap();
            pm.get(&model).map_or(0.0, |m| m.windowed_pressure)
        };
        let w0 = read(&fabric);
        assert!(w0 >= 24.0, "the burst folds into the window whole: {w0}");
        // Quiet ticks: the window must decay to a true zero (the idle
        // gate requires it) instead of pinning at the high-water mark.
        for _ in 0..8 {
            fabric.autoscale_tick();
        }
        assert_eq!(read(&fabric), 0.0, "windowed pressure decays after the storm ends");
        fabric.shutdown();
    }

    #[test]
    fn pod_crash_gives_every_queued_waiter_a_terminal_verdict() {
        // One gated replica: the first submission blocks in execution,
        // five more sit queued behind it.  Crashing the pod must seize
        // the five queued items and give each waiter a terminal verdict
        // (retried, then failed — no surviving replica), while the
        // in-flight item finishes normally when the gate opens.
        let gate = Gate::closed_gate();
        let cfg = FabricConfig {
            time_scale: 0.0,
            replicas_per_model: 1,
            queue_capacity: 8,
            workers: 1,
            resilience: ResilienceConfig {
                retry: Some(RetryPolicy::default()),
                breaker: Some(BreakerConfig::default()),
                ..Default::default()
            },
            ..Default::default()
        };
        let fabric = sim_fabric(&cfg, Some(Arc::clone(&gate)));
        let Submission::Enqueued(rx0) = fabric.submit("lenet", vec![1.0; 8]).unwrap() else {
            panic!("idle fabric must admit");
        };
        gate.await_blocked(1);
        let mut rxs = Vec::new();
        for i in 0..5 {
            match fabric.submit("lenet", vec![i as f32 + 2.0; 8]).unwrap() {
                Submission::Enqueued(rx) => rxs.push(rx),
                Submission::Shed => panic!("queue has room"),
            }
        }
        let idx = fabric.plans().iter().position(|p| p.model == "lenet").unwrap();
        let seized = fabric.inject_pod_crash(idx).unwrap();
        assert_eq!(seized, 5, "exactly the queued items are seized");
        gate.open();
        assert!(
            matches!(rx0.recv().unwrap(), Outcome::Completed(_)),
            "in-flight work finishes normally"
        );
        for rx in rxs {
            assert!(
                matches!(rx.recv().unwrap(), Outcome::Failed(_)),
                "seized work fails terminally with no surviving replica"
            );
        }
        let fleet = fabric.fleet_report(1.0);
        assert_eq!(fleet.faults_injected, 1);
        assert_eq!(fleet.retries, 5, "each seized item consumed one retry before failing");
        assert!(fleet.breaker_trips >= 1, "the crash trips the pod's breaker");
        fabric.shutdown();
    }

    #[test]
    fn prehash_separates_models_and_payloads() {
        let a = prehash("lenet", &[1.0, 2.0], !0);
        assert_eq!(a, prehash("lenet", &[1.0, 2.0], !0), "deterministic");
        assert_ne!(a, prehash("resnet50", &[1.0, 2.0], !0), "model is part of the key");
        assert_ne!(a, prehash("lenet", &[1.0, 2.5], !0), "payload is part of the key");
        assert_ne!(a, prehash("lenet", &[1.0], !0), "length is part of the key");
        assert_eq!(prehash("lenet", &[1.0, 2.0], 0x7), a & 0x7, "mask hook narrows the key");
    }

    #[test]
    fn confirm_sha_separates_models_and_payloads() {
        let a = confirm_sha("lenet", &[1.0, 2.0]);
        assert_eq!(a, confirm_sha("lenet", &[1.0, 2.0]), "deterministic");
        assert_ne!(a, confirm_sha("resnet50", &[1.0, 2.0]), "model is part of the digest");
        assert_ne!(a, confirm_sha("lenet", &[1.0, 2.5]), "payload is part of the digest");
        assert_ne!(a, confirm_sha("lenet", &[1.0]), "length is part of the digest");
    }

    #[test]
    fn forced_prehash_collisions_still_dedup_by_confirm() {
        // Mask the pre-hash down to a single bucket: every submission
        // collides at tier 1, so correctness rests entirely on the
        // sha256 confirm step.  Distinct payloads must execute
        // separately; identical ones must still collapse.
        let cfg = FabricConfig {
            dedup: true,
            prehash_mask: 0,
            workers: 1,
            time_scale: 0.0,
            ..Default::default()
        };
        let gate = Gate::closed_gate();
        let fabric = sim_fabric(&cfg, Some(Arc::clone(&gate)));
        let mut rxs = Vec::new();
        // Two distinct payloads, each submitted twice while the gate
        // holds execution: 2 leaders + 2 dedup'd followers.
        for _ in 0..2 {
            for p in [vec![1.0f32; 8], vec![2.0f32; 8]] {
                match fabric.submit("lenet", p).unwrap() {
                    Submission::Enqueued(rx) => rxs.push(rx),
                    Submission::Shed => panic!("queue has room"),
                }
            }
        }
        assert_eq!(fabric.dedup_hits(), 2, "identical payloads collapse despite collisions");
        assert!(fabric.sha_confirms() > 0, "a single-bucket mask forces tier-2 confirms");
        gate.open();
        for rx in rxs {
            assert!(matches!(rx.recv().unwrap(), Outcome::Completed(_)));
        }
        let fleet = fabric.fleet_report(1.0);
        assert_eq!(fleet.requests, 2, "exactly one execution per distinct payload");
        fabric.shutdown();
    }
}
