//! Simulated pod executors and a synthetic artifact catalog.
//!
//! The fabric can run its pods in two modes: *real* (an
//! [`crate::serving::AifServer`] per pod, which needs on-disk artifacts
//! and the PJRT runtime) and *simulated* (this module), where a pod
//! samples its service latency from the calibrated platform cost models
//! (`crate::platform`) and occupies its batcher worker for a scaled
//! slice of real time.  Simulated pods are what make the `tf2aif fabric`
//! subcommand, the cluster-scale example and the fabric integration
//! tests runnable on a machine with no artifacts built — queueing,
//! admission control, shedding and feedback behave identically in both
//! modes.

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context as _, Result};

use crate::artifact::{Artifact, Manifest};
use crate::coordinator::VARIANTS;
use crate::metrics::Collector;
use crate::platform::{self, Platform};
use crate::serving::{Prediction, Request, Response};
use crate::util::rng::Rng;

/// A test gate: while closed, simulated executors block at the start of
/// every request.  Integration tests close the gate, flood the router,
/// and get a *deterministic* accepted-count bound (queue capacity plus
/// in-worker batches) before opening it to drain.
///
/// The gate also counts how many executors are currently blocked on it
/// ([`await_blocked`](Self::await_blocked)), so tests can wait for the
/// fabric to *quiesce* at the gate instead of sleeping an arbitrary
/// settle interval and hoping the scheduler ran the workers in time.
#[derive(Debug, Default)]
pub struct Gate {
    state: Mutex<GateState>,
    /// Wakes executors blocked in [`wait_open`](Self::wait_open).
    cv: Condvar,
    /// Wakes observers blocked in [`await_blocked`](Self::await_blocked).
    settled: Condvar,
}

#[derive(Debug, Default)]
struct GateState {
    closed: bool,
    /// Executors currently parked in `wait_open`.
    waiting: usize,
}

impl Gate {
    /// A new gate, initially open.
    pub fn open_gate() -> Arc<Gate> {
        Arc::new(Gate::default())
    }

    /// A new gate, initially closed.
    pub fn closed_gate() -> Arc<Gate> {
        let g = Gate::default();
        g.state.lock().unwrap().closed = true;
        Arc::new(g)
    }

    /// Close the gate: executors block before serving their next request.
    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
    }

    /// Open the gate and wake every blocked executor (and any observer
    /// in [`await_blocked`](Self::await_blocked) — an open gate can
    /// never quiesce).
    pub fn open(&self) {
        self.state.lock().unwrap().closed = false;
        self.cv.notify_all();
        self.settled.notify_all();
    }

    /// Block while the gate is closed.
    pub fn wait_open(&self) {
        let mut g = self.state.lock().unwrap();
        while g.closed {
            g.waiting += 1;
            // An observer may be waiting for this executor to park.
            self.settled.notify_all();
            g = self.cv.wait(g).unwrap();
            g.waiting -= 1;
        }
    }

    /// Block until at least `n` executors are parked at the (closed)
    /// gate — the explicit quiesce wait that replaces "sleep and hope
    /// the workers got scheduled".  Returns immediately once the gate
    /// opens (nothing can park on an open gate).
    pub fn await_blocked(&self, n: usize) {
        let mut g = self.state.lock().unwrap();
        while g.closed && g.waiting < n {
            g = self.settled.wait(g).unwrap();
        }
    }
}

/// A simulated AIF pod: platform cost model in place of real inference.
pub struct SimPod {
    platform: &'static Platform,
    gflops: f64,
    native: bool,
    /// Fraction of the modeled service latency the executor really
    /// sleeps, so queue dynamics (and therefore shedding) are exercised
    /// without paying full simulated latencies in wall-clock.
    time_scale: f64,
    rng: Mutex<Rng>,
    metrics: Arc<Collector>,
    gate: Option<Arc<Gate>>,
    /// Device dispatches performed (one per fused batch) — the
    /// simulated analog of the real executable's dispatch counter, so
    /// `PodReport::avg_batch` proves amortization in both pod modes.
    dispatches: AtomicU64,
}

impl SimPod {
    /// Create a simulated pod serving `variant` for a model of `gflops`.
    pub fn new(
        variant: &str,
        gflops: f64,
        time_scale: f64,
        seed: u64,
        gate: Option<Arc<Gate>>,
    ) -> Result<SimPod> {
        let plat = platform::get(variant)
            .with_context(|| format!("no platform for variant {variant}"))?;
        Ok(SimPod {
            platform: plat,
            gflops,
            native: Platform::is_native_variant(variant),
            time_scale: time_scale.max(0.0),
            rng: Mutex::new(Rng::new(seed)),
            metrics: Arc::new(Collector::new()),
            gate,
            dispatches: AtomicU64::new(0),
        })
    }

    /// This pod's metrics collector.
    pub fn metrics(&self) -> &Arc<Collector> {
        &self.metrics
    }

    /// Simulated device dispatches so far (one per fused batch).
    pub fn dispatches(&self) -> u64 {
        self.dispatches.load(Ordering::Relaxed)
    }

    /// Serve one request: sample the platform cost model, occupy the
    /// worker for the scaled latency, return a deterministic prediction.
    /// A fused batch of one — identical draws and accounting.
    pub fn execute(&self, req: &Request, queue_wait_ms: f64) -> Result<Response> {
        self.execute_batch(std::slice::from_ref(req), &[queue_wait_ms]).remove(0)
    }

    /// Serve a drained batch as ONE fused dispatch: the platform's
    /// per-dispatch overhead is charged once and marginal per-item
    /// compute scales with the batch
    /// ([`Platform::batch_latency_model_ms`]), so the simulator exhibits
    /// the same amortization curve a real accelerator does.  The worker
    /// sleeps the scaled total once (one dispatch, one occupancy window),
    /// and the cost is attributed evenly across items.
    pub fn execute_batch(
        &self,
        reqs: &[Request],
        queue_wait_ms: &[f64],
    ) -> Vec<Result<Response>> {
        assert_eq!(reqs.len(), queue_wait_ms.len(), "one queue wait per request");
        if reqs.is_empty() {
            return Vec::new();
        }
        if let Some(g) = &self.gate {
            g.wait_open();
        }
        self.dispatches.fetch_add(1, Ordering::Relaxed);
        let n = reqs.len();
        let total_ms = {
            let mut rng = self.rng.lock().unwrap();
            self.platform
                .sample_batch_latency_ms(self.gflops, self.native, n, &mut rng)
        };
        let t0 = Instant::now();
        if self.time_scale > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(total_ms * self.time_scale / 1e3));
        }
        let real = t0.elapsed() / n as u32;
        let service_ms = total_ms / n as f64;
        reqs.iter()
            .zip(queue_wait_ms)
            .map(|(req, &wait)| {
                self.metrics
                    .record(service_ms, real, Duration::from_secs_f64(wait / 1e3));
                // Deterministic stand-in prediction: requests hash to a
                // class.
                let prediction = Prediction { class: (req.id % 10) as usize, score: 1.0 };
                Ok(Response {
                    id: req.id,
                    prediction,
                    service_ms,
                    real_compute_ms: real.as_secs_f64() * 1e3,
                    queue_wait_ms: wait,
                })
            })
            .collect()
    }
}

/// A zero-work pod executor: returns a canned response immediately, no
/// cost-model sampling, no sleeping, no RNG.  Everything it *doesn't* do
/// is the point — driving the fabric at saturation through `NullPod`s
/// measures pure submit→verdict router/queue/dedup overhead (the
/// `tf2aif bench --hotpath` harness), because the serving time is as
/// close to zero as the machine allows.  Metrics and the dispatch
/// counter are still recorded so conservation accounting holds.
pub struct NullPod {
    metrics: Arc<Collector>,
    dispatches: AtomicU64,
}

impl NullPod {
    /// Create a zero-work pod.
    pub fn new() -> NullPod {
        NullPod { metrics: Arc::new(Collector::new()), dispatches: AtomicU64::new(0) }
    }

    /// This pod's metrics collector.
    pub fn metrics(&self) -> &Arc<Collector> {
        &self.metrics
    }

    /// Dispatches so far (one per fused batch).
    pub fn dispatches(&self) -> u64 {
        self.dispatches.load(Ordering::Relaxed)
    }

    /// Serve one request with zero modeled work.
    pub fn execute(&self, req: &Request, queue_wait_ms: f64) -> Result<Response> {
        self.execute_batch(std::slice::from_ref(req), &[queue_wait_ms]).remove(0)
    }

    /// Serve a drained batch as one zero-cost dispatch.  The canned
    /// prediction matches [`SimPod`]'s deterministic stand-in
    /// (`class == id % 10`), so accounting-equivalence suites can swap
    /// executors without changing expected outputs.
    pub fn execute_batch(
        &self,
        reqs: &[Request],
        queue_wait_ms: &[f64],
    ) -> Vec<Result<Response>> {
        assert_eq!(reqs.len(), queue_wait_ms.len(), "one queue wait per request");
        if reqs.is_empty() {
            return Vec::new();
        }
        self.dispatches.fetch_add(1, Ordering::Relaxed);
        reqs.iter()
            .zip(queue_wait_ms)
            .map(|(req, &wait)| {
                self.metrics.record(0.0, Duration::ZERO, Duration::from_secs_f64(wait / 1e3));
                let prediction = Prediction { class: (req.id % 10) as usize, score: 1.0 };
                Ok(Response {
                    id: req.id,
                    prediction,
                    service_ms: 0.0,
                    real_compute_ms: 0.0,
                    queue_wait_ms: wait,
                })
            })
            .collect()
    }
}

impl Default for NullPod {
    fn default() -> Self {
        NullPod::new()
    }
}

/// Per-model (gflops, weights_bytes, input_shape) for the synthetic
/// catalog — the Table III scale the repo's python exporter produces.
const MODEL_SPECS: &[(&str, f64, u64, [usize; 4])] = &[
    ("lenet", 0.001, 200_000, [1, 32, 32, 1]),
    ("mobilenetv1", 0.025, 4_000_000, [1, 64, 64, 3]),
    ("resnet50", 0.168, 25_000_000, [1, 64, 64, 3]),
    ("inceptionv4", 0.529, 43_000_000, [1, 75, 75, 3]),
];

/// Build an in-memory artifact catalog covering every Table III model ×
/// Table I accelerated variant, with manifests carrying the measured
/// GFLOPs/weight scales.  No files are read or written: simulated pods
/// never open `model.hlo.txt`, so the backend can rank and the fabric can
/// place without `make artifacts` having run.
pub fn synthetic_catalog() -> Vec<Artifact> {
    let mut out = Vec::new();
    for (model, gflops, weights_bytes, input_shape) in MODEL_SPECS {
        for variant in VARIANTS {
            let plat = platform::get(variant).expect("catalog variant");
            let manifest = Manifest {
                model: model.to_string(),
                variant: variant.to_string(),
                platform: plat.hw.to_string(),
                framework: plat.framework.to_string(),
                precision: plat.precision.to_string(),
                mode: if plat.precision == "INT8" { "int8" } else { "fp32" }.to_string(),
                baseline_of: String::new(),
                input_shape: input_shape.to_vec(),
                output_shape: vec![1, 10],
                params: Vec::new(),
                fixtures: Vec::new(),
                param_count: weights_bytes / 4,
                weights_bytes: *weights_bytes,
                master_size_mb: *weights_bytes as f64 / 1e6,
                macs: (*gflops * 5e8) as u64,
                gflops: *gflops,
                layers: 0,
                convert_time_s: 0.0,
                lower_time_s: 0.0,
                calibration_scheme: "simulated".to_string(),
            };
            out.push(Artifact {
                dir: PathBuf::from(format!("sim://{model}_{variant}")),
                manifest,
            });
        }
    }
    out
}

/// [`synthetic_catalog`] restricted to the named models (all of them
/// when `models` is empty) — the filter every single-model fabric test
/// and bench drive performs, in one place.
pub fn synthetic_catalog_for(models: &[&str]) -> Vec<Artifact> {
    synthetic_catalog()
        .into_iter()
        .filter(|a| models.is_empty() || models.contains(&a.manifest.model.as_str()))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::MODELS;

    #[test]
    fn catalog_covers_models_times_variants() {
        let c = synthetic_catalog();
        assert_eq!(c.len(), MODELS.len() * VARIANTS.len());
        for a in &c {
            assert!(a.manifest.gflops > 0.0);
            assert!(a.manifest.weights_bytes > 0);
            assert_eq!(a.manifest.input_shape.len(), 4, "NHWC");
        }
    }

    #[test]
    fn catalog_filter_selects_models() {
        let c = synthetic_catalog_for(&["lenet"]);
        assert!(!c.is_empty());
        assert!(c.iter().all(|a| a.manifest.model == "lenet"));
        assert_eq!(synthetic_catalog_for(&[]).len(), synthetic_catalog().len());
    }

    #[test]
    fn sim_pod_records_metrics() {
        let pod = SimPod::new("GPU", 0.1, 0.0, 7, None).unwrap();
        let resp = pod
            .execute(&Request { id: 3, payload: vec![0.0; 4].into() }, 1.5)
            .unwrap();
        assert_eq!(resp.id, 3);
        assert_eq!(resp.prediction.class, 3);
        assert!(resp.service_ms > 0.0);
        assert!((resp.queue_wait_ms - 1.5).abs() < 1e-12);
        let snap = pod.metrics().snapshot();
        assert_eq!(snap.requests, 1);
    }

    #[test]
    fn fused_batch_amortizes_platform_overhead() {
        let pod = SimPod::new("GPU", 0.025, 0.0, 9, None).unwrap();
        let reqs: Vec<Request> =
            (0..8).map(|i| Request { id: i, payload: Vec::new().into() }).collect();
        let out = pod.execute_batch(&reqs, &[0.0; 8]);
        assert_eq!(out.len(), 8);
        let batched_ms = out[0].as_ref().unwrap().service_ms;
        let single_ms = pod.execute(&reqs[0], 0.0).unwrap().service_ms;
        assert!(
            batched_ms < single_ms,
            "fused per-item {batched_ms} must beat per-item dispatch {single_ms}"
        );
        assert_eq!(pod.metrics().snapshot().requests, 9);
        assert_eq!(pod.dispatches(), 2, "one fused batch + one single = two dispatches");
    }

    #[test]
    fn gate_blocks_until_open() {
        let gate = Gate::closed_gate();
        let pod =
            Arc::new(SimPod::new("CPU", 0.001, 0.0, 1, Some(Arc::clone(&gate))).unwrap());
        let p2 = Arc::clone(&pod);
        let h = std::thread::spawn(move || {
            p2.execute(&Request { id: 0, payload: Vec::new().into() }, 0.0).unwrap()
        });
        // Explicit quiesce: wait until the executor is provably parked
        // at the gate (no arbitrary settle sleep, no scheduler races).
        gate.await_blocked(1);
        assert_eq!(pod.metrics().snapshot().requests, 0, "gated executor must not serve");
        gate.open();
        let resp = h.join().unwrap();
        assert_eq!(resp.id, 0);
        assert_eq!(pod.metrics().snapshot().requests, 1);
    }

    #[test]
    fn unknown_variant_rejected() {
        assert!(SimPod::new("NPU", 1.0, 0.0, 1, None).is_err());
    }
}
