//! # TF2AIF — accelerated AI-function generation and serving
//!
//! Reproduction of *"TF2AIF: Facilitating development and deployment of
//! accelerated AI models on the cloud-edge continuum"* (EuCNC/6G Summit
//! 2024) as a three-layer Rust + JAX + Pallas stack:
//!
//! - **Layer 1 (Pallas)** — precision-specialized GEMM kernels
//!   (`python/compile/kernels/`), the stand-ins for TensorRT / TFLite /
//!   Vitis-AI compute paths.
//! - **Layer 2 (JAX)** — the Table III model zoo, converter (BN folding,
//!   PTQ calibration, quantization) and AOT export to HLO text
//!   (`python/compile/`).  Python runs once, at build time.
//! - **Layer 3 (this crate)** — the TF2AIF system itself: the
//!   Converter/Composer generation pipeline, the bundle registry, the
//!   Kubernetes-substrate cluster simulator, the variant-selection
//!   backend, and the AIF serving runtime over PJRT.
//!
//! See `DESIGN.md` for the full system inventory and the experiment index
//! mapping every paper table/figure to a module + bench.

pub mod artifact;
pub mod backend;
pub mod client;
pub mod cluster;
pub mod composer;
pub mod config;
pub mod converter;
pub mod coordinator;
pub mod metrics;
pub mod platform;
pub mod registry;
pub mod report;
pub mod runtime;
pub mod serving;
pub mod util;
pub mod workload;

/// Repo-relative default artifact directory.
pub const ARTIFACTS_DIR: &str = "artifacts";
