//! # TF2AIF — accelerated AI-function generation and serving
//!
//! Reproduction of *"TF2AIF: Facilitating development and deployment of
//! accelerated AI models on the cloud-edge continuum"* (EuCNC/6G Summit
//! 2024) as a three-layer Rust + JAX + Pallas stack:
//!
//! - **Layer 1 (Pallas)** — precision-specialized GEMM kernels
//!   (`python/compile/kernels/`), the stand-ins for TensorRT / TFLite /
//!   Vitis-AI compute paths.
//! - **Layer 2 (JAX)** — the Table III model zoo, converter (BN folding,
//!   PTQ calibration, quantization) and AOT export to HLO text
//!   (`python/compile/`).  Python runs once, at build time.
//! - **Layer 3 (this crate)** — the TF2AIF system itself: the
//!   Converter/Composer generation pipeline ([`converter`], [`composer`],
//!   [`registry`]), the Kubernetes-substrate cluster simulator
//!   ([`cluster`]), the variant-selection backend ([`backend`]), the AIF
//!   serving runtime over PJRT ([`runtime`], [`serving`]), and the
//!   cluster-scale serving fabric ([`fabric`]) that routes live traffic
//!   across every placed variant, and the continuum orchestrator
//!   ([`continuum`]) that plans and serves across multiple sites with
//!   spillover and failure-driven replanning.
//!
//! See `docs/ARCHITECTURE.md` for the paper-concept → module map and the
//! request lifecycle, and `docs/CLI.md` for the `tf2aif` command-line
//! surface.
//!
//! ## Worked example: shard a fleet, route traffic, adapt placement
//!
//! The fabric runs end-to-end on simulated pods (no artifacts needed), so
//! this example is self-contained:
//!
//! ```
//! use tf2aif::backend::{Backend, Policy};
//! use tf2aif::cluster::{paper_testbed, Cluster};
//! use tf2aif::fabric::{sim, Fabric, FabricConfig};
//! use tf2aif::workload::Arrival;
//!
//! // Table II testbed; the Kube-API extension registers ARM devices.
//! let mut cluster = Cluster::new(paper_testbed());
//! cluster.apply_kube_api_extension();
//!
//! // Backend indexes one artifact per (model × variant); the fabric
//! // takes ownership of the cluster, shards every model across
//! // distinct nodes and spawns per-pod batcher workers behind bounded
//! // admission queues.  `adaptive` lets each pod's controller pick its
//! // own drain size from backlog + latency feedback.
//! let mut backend = Backend::new(sim::synthetic_catalog(), Policy::MinLatency);
//! let cfg = FabricConfig { time_scale: 0.0, adaptive: true, ..Default::default() };
//! let fabric = Fabric::place_sim(&backend, cluster, &cfg, None).unwrap();
//! assert!(fabric.nodes_spanned().len() >= 3);
//!
//! // Route a small workload; every request is completed or shed,
//! // never silently dropped.
//! let run = fabric.run(32, Arrival::ClosedLoop, 7).unwrap();
//! assert!(run.fully_accounted());
//!
//! // Measured latencies feed back into placement scoring.
//! backend.feedback = Some(fabric.feedback());
//! let d = fabric
//!     .with_cluster(|cluster| backend.rank("lenet", cluster))
//!     .unwrap()
//!     .remove(0);
//! assert!(d.estimated_ms.is_finite());
//! fabric.shutdown();
//! ```

#![warn(missing_docs)]

pub mod artifact;
pub mod backend;
pub mod client;
pub mod cluster;
pub mod composer;
pub mod config;
pub mod continuum;
pub mod converter;
pub mod coordinator;
pub mod fabric;
pub mod manifest;
pub mod metrics;
pub mod platform;
pub mod registry;
pub mod report;
pub mod runtime;
pub mod serving;
pub mod util;
pub mod workload;

/// Repo-relative default artifact directory.
pub const ARTIFACTS_DIR: &str = "artifacts";
