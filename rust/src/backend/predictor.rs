//! Learned latency predictor — the paper's Objective #4 ("enable
//! AI-driven inference serving scheduling systems") and declared future
//! work ("the ease and speed of generating performance data are vital in
//! empowering AI/ML-driven schedulers").
//!
//! TF2AIF's benchmark sweep (`examples/benchmark_sweep.rs`) generates
//! exactly the dataset this needs: (platform, precision, model-FLOPs) →
//! measured mean service latency.  A ridge-regularized least-squares
//! model over [1, gflops, platform one-hots, gflops×platform, native]
//! recovers the latency surface; the backend can then rank placements
//! from *data* instead of the analytic cost model.


use anyhow::{bail, Result};

/// One training observation from a benchmark sweep.
#[derive(Debug, Clone)]
pub struct Observation {
    /// Platform name.
    pub platform: String,
    /// Whether the native-TF path was measured.
    pub native: bool,
    /// Model compute cost, GFLOPs.
    pub gflops: f64,
    /// Measured mean service latency, ms.
    pub mean_latency_ms: f64,
}

/// Ridge-regression latency model.
#[derive(Debug, Clone)]
pub struct LearnedLatency {
    platforms: Vec<String>,
    weights: Vec<f64>,
}

impl LearnedLatency {
    /// Feature vector: global [1, g] plus a per-(platform × native) cell
    /// intercept and slope — the latency surface is exactly
    /// `overhead(cell) + g / throughput(cell)`, so the model class
    /// realizes it and the fit is identifiable from sweep data alone.
    fn features(&self, platform: &str, gflops: f64, native: bool) -> Vec<f64> {
        let p = self.platforms.len();
        let cells = 2 * p;
        let mut f = vec![0.0; 2 + 2 * cells];
        f[0] = 1.0;
        f[1] = gflops;
        if let Some(i) = self.platforms.iter().position(|q| q == platform) {
            let cell = 2 * i + native as usize;
            f[2 + cell] = 1.0;
            f[2 + cells + cell] = gflops;
        }
        f
    }

    /// Fit by solving the ridge normal equations (tiny dims — Gaussian
    /// elimination with partial pivoting is plenty).
    pub fn fit(data: &[Observation]) -> Result<LearnedLatency> {
        if data.len() < 4 {
            bail!("need at least 4 observations, got {}", data.len());
        }
        let mut platforms: Vec<String> = data.iter().map(|o| o.platform.clone()).collect();
        platforms.sort();
        platforms.dedup();
        let mut model = LearnedLatency { platforms, weights: vec![] };
        let d = 2 + 4 * model.platforms.len();
        let mut xtx = vec![vec![0.0; d]; d];
        let mut xty = vec![0.0; d];
        for o in data {
            let f = model.features(&o.platform, o.gflops, o.native);
            for i in 0..d {
                xty[i] += f[i] * o.mean_latency_ms;
                for j in 0..d {
                    xtx[i][j] += f[i] * f[j];
                }
            }
        }
        // Ridge: keeps unobserved platform columns solvable.
        for (i, row) in xtx.iter_mut().enumerate() {
            row[i] += 1e-6;
        }
        model.weights = solve(xtx, xty)?;
        Ok(model)
    }

    /// Predicted mean service latency in ms (clamped non-negative).
    pub fn predict(&self, platform: &str, gflops: f64, native: bool) -> f64 {
        let f = self.features(platform, gflops, native);
        f.iter().zip(&self.weights).map(|(a, b)| a * b).sum::<f64>().max(0.0)
    }

    /// Mean absolute percentage error over a dataset.
    pub fn mape(&self, data: &[Observation]) -> f64 {
        let mut acc = 0.0;
        for o in data {
            let p = self.predict(&o.platform, o.gflops, o.native);
            acc += ((p - o.mean_latency_ms) / o.mean_latency_ms).abs();
        }
        acc / data.len() as f64
    }

    /// Platforms the model was trained over.
    pub fn platforms(&self) -> &[String] {
        &self.platforms
    }
}

/// Gaussian elimination with partial pivoting.
fn solve(mut a: Vec<Vec<f64>>, mut b: Vec<f64>) -> Result<Vec<f64>> {
    let n = b.len();
    for col in 0..n {
        // Pivot.
        let piv = (col..n)
            .max_by(|&i, &j| a[i][col].abs().partial_cmp(&a[j][col].abs()).unwrap())
            .unwrap();
        if a[piv][col].abs() < 1e-12 {
            bail!("singular normal equations");
        }
        a.swap(col, piv);
        b.swap(col, piv);
        for row in col + 1..n {
            let f = a[row][col] / a[col][col];
            for k in col..n {
                a[row][k] -= f * a[col][k];
            }
            b[row] -= f * b[col];
        }
    }
    let mut x = vec![0.0; n];
    for col in (0..n).rev() {
        let mut s = b[col];
        for k in col + 1..n {
            s -= a[col][k] * x[k];
        }
        x[col] = s / a[col][col];
    }
    Ok(x)
}

/// Generate a training set from the analytic platform models — stands in
/// for a recorded sweep when `reports/sweep.csv` is absent.  `noise`
/// perturbs the labels (measurement realism).
pub fn synthetic_sweep(noise: f64, seed: u64) -> Vec<Observation> {
    use crate::platform::PLATFORMS;
    use crate::util::rng::Rng;
    let mut rng = Rng::new(seed);
    let mut out = Vec::new();
    for p in PLATFORMS {
        for i in 0..24 {
            let gflops = 0.0005 * 1.35f64.powi(i);
            for native in [false, true] {
                if native && p.native_gflops == 0.0 {
                    continue;
                }
                let base = p.latency_model_ms(gflops, native);
                out.push(Observation {
                    platform: p.name.to_string(),
                    native,
                    gflops,
                    mean_latency_ms: base * (1.0 + noise * rng.normal()),
                });
            }
        }
    }
    out
}

/// Parse observations out of a `reports/sweep.csv` produced by the
/// benchmark_sweep example.
pub fn from_sweep_csv(path: &str) -> Result<Vec<Observation>> {
    let src = std::fs::read_to_string(path)?;
    let mut lines = src.lines();
    let header: Vec<&str> = lines.next().unwrap_or("").split(',').collect();
    let col = |name: &str| header.iter().position(|h| *h == name);
    let (Some(vi), Some(gi), Some(mi)) =
        (col("variant"), col("gflops"), col("service_mean_ms"))
    else {
        bail!("sweep.csv missing columns");
    };
    let mut out = Vec::new();
    for line in lines {
        let f: Vec<&str> = line.split(',').collect();
        if f.len() <= mi.max(gi).max(vi) {
            continue;
        }
        let variant = f[vi];
        out.push(Observation {
            platform: variant.trim_end_matches("_TF").to_string(),
            native: variant.ends_with("_TF"),
            gflops: f[gi].parse()?,
            mean_latency_ms: f[mi].parse()?,
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use std::collections::BTreeMap;

    use super::*;

    #[test]
    fn recovers_cost_model_ordering() {
        let data = synthetic_sweep(0.02, 1);
        let m = LearnedLatency::fit(&data).unwrap();
        // Large model: learned ranking must match Fig. 4's.
        let g = 0.529;
        let lat: BTreeMap<&str, f64> = ["GPU", "ALVEO", "AGX", "CPU", "ARM"]
            .iter()
            .map(|p| (*p, m.predict(p, g, false)))
            .collect();
        assert!(lat["GPU"] < lat["ALVEO"]);
        assert!(lat["ALVEO"] < lat["AGX"]);
        assert!(lat["AGX"] < lat["CPU"]);
        assert!(lat["CPU"] < lat["ARM"]);
    }

    #[test]
    fn fit_error_is_small_on_clean_data() {
        let data = synthetic_sweep(0.0, 2);
        let m = LearnedLatency::fit(&data).unwrap();
        assert!(m.mape(&data) < 0.05, "mape {}", m.mape(&data));
    }

    #[test]
    fn predicts_native_slower_than_accelerated() {
        let m = LearnedLatency::fit(&synthetic_sweep(0.02, 3)).unwrap();
        for p in ["AGX", "ARM", "CPU", "GPU"] {
            for g in [0.01, 0.1, 0.5] {
                assert!(
                    m.predict(p, g, true) > m.predict(p, g, false),
                    "{p} at {g}"
                );
            }
        }
    }

    #[test]
    fn rejects_tiny_datasets() {
        assert!(LearnedLatency::fit(&[]).is_err());
    }

    #[test]
    fn unknown_platform_gets_global_trend() {
        let m = LearnedLatency::fit(&synthetic_sweep(0.0, 4)).unwrap();
        let a = m.predict("NPU", 0.1, false);
        let b = m.predict("NPU", 0.5, false);
        assert!(a.is_finite() && b.is_finite());
        assert!(b >= a, "latency must grow with FLOPs even off-registry");
    }
}
