//! Deployment backend — the paper's §V-C "backend system, which operates
//! in conjunction with Kubernetes \[and\], considering the available
//! hardware, automatically determines the most suitable
//! AI-framework-platform model variant for deployment".
//!
//! Selection is a pure function over (artifact index, cluster state,
//! policy); `Deployment` couples a decision to a bound pod and a live
//! `AifServer`.  The multi-objective policies beyond `MinLatency` are the
//! paper's declared future work — implemented here as the natural
//! extensions (DESIGN.md: optional/extension features).

pub mod predictor;

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::artifact::Artifact;
use crate::cluster::Cluster;
use crate::metrics::FeedbackStore;
use crate::platform::{self, Platform};
use crate::runtime::Engine;
use crate::serving::{AifServer, ImageClassify};

/// Variant-selection policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// Minimize modeled service latency (the paper's implied default).
    MinLatency,
    /// Prefer far-edge placements (FE nodes), tie-break on latency —
    /// keeps near-edge servers free for heavier AIFs.
    PreferEdge,
    /// Minimize modeled joules/request: the platform's
    /// utilization-scaled power model ([`Platform::power_w`]) over the
    /// (feedback-blended) latency estimate, evaluated at saturation —
    /// placement assumes a busy pod; delivered utilization is what the
    /// continuum's per-site energy accounting measures after the fact.
    MinEnergy,
}

impl Policy {
    /// Parse a CLI policy name.
    pub fn parse(s: &str) -> Result<Policy> {
        Ok(match s {
            "min-latency" => Policy::MinLatency,
            "prefer-edge" => Policy::PreferEdge,
            "min-energy" => Policy::MinEnergy,
            other => bail!("unknown policy {other:?}"),
        })
    }
}

/// One placement decision.
#[derive(Debug, Clone)]
pub struct Decision {
    /// AIF identity (`model_variant`).
    pub aif: String,
    /// Selected platform variant.
    pub variant: String,
    /// Target cluster node.
    pub node: String,
    /// Modeled (noise-free) service latency from the platform cost
    /// model, ms.
    pub modeled_ms: f64,
    /// Latency estimate actually used for ranking: the modeled latency
    /// blended with measured fabric feedback when a [`FeedbackStore`] is
    /// attached (equals `modeled_ms` otherwise).
    pub estimated_ms: f64,
    /// Policy score (lower is better).
    pub score: f64,
}

/// The backend: an index of available artifacts + a policy.
pub struct Backend {
    /// model name → its artifacts (all variants found on disk).  Shared
    /// (`Arc`) so catalog snapshots, continuum replans and autoscaler
    /// scale-ups move a refcount instead of cloning weight bytes.
    index: BTreeMap<String, Vec<Arc<Artifact>>>,
    /// Active selection policy.
    pub policy: Policy,
    /// Consider native `*_TF` variants during selection (off by default —
    /// the paper deploys accelerated variants; baselines are for Fig. 5).
    pub allow_native: bool,
    /// When set, latency estimates come from the ML-trained model
    /// (Objective #4) instead of the analytic platform cost model.
    pub predictor: Option<predictor::LearnedLatency>,
    /// When set, per-(variant, node) latency observations measured by the
    /// serving fabric are blended into placement scores, so ranking
    /// adapts to delivered performance instead of static platform
    /// rankings (the fabric's feedback loop).
    pub feedback: Option<Arc<FeedbackStore>>,
}

impl Backend {
    /// Index artifacts by model under a policy (each artifact is moved
    /// behind an `Arc` exactly once, here).
    pub fn new(artifacts: Vec<Artifact>, policy: Policy) -> Backend {
        Backend::from_shared(artifacts.into_iter().map(Arc::new).collect(), policy)
    }

    /// Index an already-shared catalog (continuum replans and the
    /// autoscaler rebuild backends over the same artifacts — this path
    /// bumps refcounts instead of cloning weight bytes).
    pub fn from_shared(artifacts: Vec<Arc<Artifact>>, policy: Policy) -> Backend {
        let mut index: BTreeMap<String, Vec<Arc<Artifact>>> = BTreeMap::new();
        for a in artifacts {
            index.entry(a.manifest.model.clone()).or_default().push(a);
        }
        Backend { index, policy, allow_native: false, predictor: None, feedback: None }
    }

    /// All model names with artifacts, sorted.
    pub fn models(&self) -> Vec<&str> {
        self.index.keys().map(String::as_str).collect()
    }

    /// Every artifact (variant) of a model, as shared handles.
    pub fn variants_of(&self, model: &str) -> Vec<&Arc<Artifact>> {
        self.index.get(model).map(|v| v.iter().collect()).unwrap_or_default()
    }

    /// Memory an AIF instance pins on a node, GB (weights + runtime pad).
    /// Public so the serving fabric can bind replica pods itself.
    pub fn pod_memory_gb(a: &Artifact) -> f64 {
        a.manifest.weights_bytes as f64 / 1e9 + 0.25
    }

    /// Rank all feasible (variant, node) placements for `model`.
    pub fn rank(&self, model: &str, cluster: &Cluster) -> Result<Vec<Decision>> {
        let artifacts = self
            .index
            .get(model)
            .with_context(|| format!("no artifacts for model {model:?}"))?;
        let mut out = Vec::new();
        for a in artifacts {
            let m = &a.manifest;
            if !self.allow_native && Platform::is_native_variant(&m.variant) {
                continue;
            }
            let Some(plat) = platform::get(&m.variant) else { continue };
            let native = Platform::is_native_variant(&m.variant);
            let modeled = match &self.predictor {
                Some(p) => p.predict(plat.name, m.gflops, native),
                None => plat.latency_model_ms(m.gflops, native),
            };
            for node in cluster.feasible_nodes(&m.variant, Self::pod_memory_gb(a)) {
                // Fabric feedback: prefer what the pod actually delivered
                // over the static model once observations exist.  Keyed by
                // the full AIF id — observations of other models on this
                // (variant, node) must not leak in.
                let estimated = match &self.feedback {
                    Some(f) => f.blend(&FeedbackStore::key(&m.id(), &node.name), modeled),
                    None => modeled,
                };
                let score = match self.policy {
                    Policy::MinLatency => estimated,
                    Policy::PreferEdge => {
                        // Far-edge nodes (arm64) win by a large margin,
                        // latency breaks ties.
                        if node.arch == "arm64" { estimated } else { estimated + 1e6 }
                    }
                    // Modeled joules/request at saturation: the board's
                    // peak draw over the estimated service time.
                    Policy::MinEnergy => plat.energy_j(estimated, 1.0),
                };
                out.push(Decision {
                    aif: m.id(),
                    variant: m.variant.clone(),
                    node: node.name.clone(),
                    modeled_ms: modeled,
                    estimated_ms: estimated,
                    score,
                });
            }
        }
        out.sort_by(|a, b| a.score.partial_cmp(&b.score).unwrap());
        Ok(out)
    }

    /// Pick the best placement (the paper's automatic selection).
    pub fn select(&self, model: &str, cluster: &Cluster) -> Result<Decision> {
        self.rank(model, cluster)?
            .into_iter()
            .next()
            .with_context(|| format!("no feasible placement for {model:?}"))
    }

    /// Select, bind the pod, compile + pin the AIF, return the live
    /// deployment.
    pub fn deploy(
        &self,
        model: &str,
        cluster: &mut Cluster,
        engine: &Engine,
    ) -> Result<Deployment> {
        let d = self.select(model, cluster)?;
        let artifact = self
            .index
            .get(model)
            .unwrap()
            .iter()
            .find(|a| a.manifest.variant == d.variant)
            .unwrap();
        let pod = cluster.bind(&d.aif, &d.variant, &d.node, Self::pod_memory_gb(artifact))?;
        // Shared with the runtime host — a refcount bump, not a clone.
        let artifact = Arc::clone(artifact);
        let server = AifServer::deploy(engine, &artifact, Arc::new(ImageClassify))?;
        Ok(Deployment { decision: d, pod, server: Arc::new(server) })
    }
}

/// A live deployment: decision + pod binding + serving instance.
pub struct Deployment {
    /// The ranked decision that was executed.
    pub decision: Decision,
    /// Bound pod id.
    pub pod: u64,
    /// The live serving instance.
    pub server: Arc<AifServer>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::paper_testbed;

    fn load_backend(policy: Policy) -> Option<(Backend, Cluster)> {
        let arts = crate::artifact::scan("artifacts").ok()?;
        if arts.is_empty() {
            return None;
        }
        let mut cluster = Cluster::new(paper_testbed());
        cluster.apply_kube_api_extension();
        Some((Backend::new(arts, policy), cluster))
    }

    #[test]
    fn min_latency_picks_gpu_for_large_models() {
        let Some((b, c)) = load_backend(Policy::MinLatency) else { return };
        let d = b.select("inceptionv4", &c).unwrap();
        assert_eq!(d.variant, "GPU", "V100 wins large CNNs (Fig. 4)");
        assert_eq!(d.node, "NE-2");
    }

    #[test]
    fn prefer_edge_lands_on_fe() {
        let Some((b, c)) = load_backend(Policy::PreferEdge) else { return };
        let d = b.select("mobilenetv1", &c).unwrap();
        assert_eq!(d.node, "FE");
        assert!(d.variant == "AGX" || d.variant == "ARM");
    }

    #[test]
    fn native_variants_excluded_by_default() {
        let Some((b, c)) = load_backend(Policy::MinLatency) else { return };
        for d in b.rank("resnet50", &c).unwrap() {
            assert!(!d.variant.ends_with("_TF"), "{}", d.variant);
        }
    }

    #[test]
    fn ranking_is_sorted() {
        let Some((b, c)) = load_backend(Policy::MinLatency) else { return };
        let r = b.rank("lenet", &c).unwrap();
        assert!(!r.is_empty());
        for w in r.windows(2) {
            assert!(w[0].score <= w[1].score);
        }
    }

    #[test]
    fn min_energy_prefers_the_low_power_edge_module() {
        // Synthetic catalog: no on-disk artifacts required.  On
        // joules/request the 30 W AGX module undercuts every server
        // part for a large CNN, even though the V100 is faster.
        let arts = crate::fabric::sim::synthetic_catalog();
        let mut cluster = Cluster::new(paper_testbed());
        cluster.apply_kube_api_extension();
        let b = Backend::new(arts, Policy::MinEnergy);
        let d = b.select("inceptionv4", &cluster).unwrap();
        assert_eq!(d.variant, "AGX");
        assert_eq!(d.node, "FE");
        // The score IS the modeled joules/request at saturation.
        let plat = platform::get("AGX").unwrap();
        assert!((d.score - plat.energy_j(d.estimated_ms, 1.0)).abs() < 1e-12);
    }

    #[test]
    fn fabric_feedback_rescores_placements() {
        // Synthetic catalog: no on-disk artifacts required.
        let arts = crate::fabric::sim::synthetic_catalog();
        let mut cluster = Cluster::new(paper_testbed());
        cluster.apply_kube_api_extension();
        let mut b = Backend::new(arts, Policy::MinLatency);

        let cold = b.select("inceptionv4", &cluster).unwrap();
        assert_eq!(cold.variant, "GPU", "cost model favors the V100");
        assert!((cold.estimated_ms - cold.modeled_ms).abs() < 1e-12, "no feedback yet");

        // The fabric measured the GPU pod badly degraded (say, a noisy
        // neighbor): 100 observations at 50 ms.
        let store = Arc::new(FeedbackStore::new(0.3));
        let key = FeedbackStore::key("inceptionv4_GPU", "NE-2");
        for _ in 0..100 {
            store.observe(&key, 50.0, 0.0);
        }
        b.feedback = Some(Arc::clone(&store));
        let warm = b.select("inceptionv4", &cluster).unwrap();
        assert_ne!(
            (warm.variant.as_str(), warm.node.as_str()),
            ("GPU", "NE-2"),
            "measured degradation must dethrone the static winner"
        );
        // The degraded pod's estimate reflects the measurement.
        let gpu = b
            .rank("inceptionv4", &cluster)
            .unwrap()
            .into_iter()
            .find(|d| d.variant == "GPU" && d.node == "NE-2")
            .unwrap();
        assert!(gpu.estimated_ms > 40.0, "estimated {}", gpu.estimated_ms);
    }
}
