//! `tf2aif` — the leader CLI.
//!
//! Subcommands mirror the paper's workflow:
//!
//! - `build`    — Converter ∥ Composer ∥ Registry (generate AIF bundles).
//! - `verify`   — fixture parity of every artifact through PJRT.
//! - `serve`    — deploy one AIF and run the generated client against it.
//! - `cluster`  — Table II cluster simulation + backend auto-placement.
//! - `fabric`   — cluster-scale serving: shard every AIF across the
//!   testbed, route an open-loop workload with admission control, report
//!   per-node + fleet tables (see `docs/CLI.md`).
//! - `continuum` — multi-site orchestration: plan placements across
//!   cloud/edge/far-edge sites under a latency/energy policy, route a
//!   workload with spillover, kill sites mid-stream and replan.
//! - `apply`    — declarative deployment: parse a versioned manifest,
//!   `--plan` the canonical action diff against the applied state (exit
//!   2 on drift), converge a live continuum `--from` the previous
//!   manifest mid-traffic, `--watch` the file and re-converge on change.
//! - `bench`    — fabric sweeps: fused vs per-item, adaptive vs fixed
//!   batch sizing, fixed replicas vs autoscaler, tenancy fairness, and
//!   the continuum scenario verdicts; writes `BENCH_fabric.json`.
//!   `--hotpath` instead runs the submit→verdict overhead harness at
//!   saturation over zero-work pods (schema v7 `hotpath` section).
//! - `report`   — regenerate paper tables/figures (table1..3, fig3..5).

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use tf2aif::backend::{Backend, Policy};
use tf2aif::client::{Client, ClientConfig};
use tf2aif::cluster::{paper_testbed, Cluster};
use tf2aif::config::Config;
use tf2aif::continuum::{self, ContinuumOrchestrator, PlanPolicy, Topology};
use tf2aif::coordinator::{self, Fig4Options, GenerateOptions};
use tf2aif::fabric::bench::{self, BenchConfig};
use tf2aif::fabric::des::{
    run_des, DesAutoscale, DesConfig, DesModel, DesReport, DesScenario, DesSite, Drill,
};
use tf2aif::fabric::tenancy::{apply_tenant_slos, parse_tenant_specs, TenantSpec};
use tf2aif::fabric::{
    sim, AutoscaleConfig, BreakerConfig, BrownoutConfig, Fabric, FabricConfig, Fault,
    FaultPlan, HedgePolicy, ResilienceConfig, RetryPolicy,
};
use tf2aif::manifest::canonical::{content_hash, render_json, sha256_hex};
use tf2aif::manifest::diff::{diff, ConvergencePlan};
use tf2aif::manifest::reconcile::{
    deploy_manifest_sim, drive, reconcile, run_scenarios as run_manifest_scenarios, settle,
    ApplyReport, DrivePhase,
};
use tf2aif::manifest::DeploymentManifest;
use tf2aif::report;
use tf2aif::runtime::Engine;
use tf2aif::util::json::{self as json, Json};
use tf2aif::serving::{AifServer, ImageClassify};
use tf2aif::workload::{read_trace_csv, Arrival, RateCurve, TenantMix};
use tf2aif::{artifact, ARTIFACTS_DIR};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Tiny flag parser: `--key value` pairs after the subcommand.
struct Flags<'a> {
    args: &'a [String],
}

impl<'a> Flags<'a> {
    fn get(&self, key: &str) -> Option<&str> {
        self.args
            .iter()
            .position(|a| a == key)
            .and_then(|i| self.args.get(i + 1))
            .map(String::as_str)
    }

    fn has(&self, key: &str) -> bool {
        self.args.iter().any(|a| a == key)
    }

    fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            Some(v) => v.parse().with_context(|| format!("bad {key}: {v:?}")),
            None => Ok(default),
        }
    }

    fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            Some(v) => v.parse().with_context(|| format!("bad {key}: {v:?}")),
            None => Ok(default),
        }
    }
}

fn run(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first() else {
        print_usage();
        return Ok(());
    };
    let flags = Flags { args: &args[1..] };
    match cmd.as_str() {
        "build" => cmd_build(&flags),
        "verify" => cmd_verify(&flags),
        "serve" => cmd_serve(&flags),
        "cluster" => cmd_cluster(&flags),
        "fabric" => cmd_fabric(&flags),
        "continuum" => cmd_continuum(&flags),
        "apply" => cmd_apply(&flags),
        "bench" => cmd_bench(&flags),
        "report" => cmd_report(&flags),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => bail!("unknown command {other:?} (try `tf2aif help`)"),
    }
}

fn print_usage() {
    println!(
        "tf2aif — accelerated AI-function generation and serving\n\n\
         USAGE: tf2aif <command> [flags]\n\n\
         COMMANDS:\n  \
         build    [--models a,b] [--variants x,y] [--jobs N] [--force] [--native]\n  \
         verify   [--artifacts DIR]\n  \
         serve    --aif <model_variant> [--requests N] [--rps R]\n  \
         cluster  [--config FILE] [--policy min-latency|prefer-edge|min-energy] [--model M]\n  \
         fabric   [--requests N] [--arrival closed|poisson:RPS|uniform:RPS] [--models a,b]\n           \
         [--replicas N] [--queue N] [--batch N] [--workers N] [--policy P]\n           \
         [--config FILE] [--real] [--time-scale F] [--seed N] [--run-seed N]\n           \
         [--per-item] [--no-dedup] [--adaptive] [--min-batch N] [--slo MS]\n           \
         [--linger MS] [--cache N] [--cache-ttl MS] [--autoscale MIN:MAX]\n           \
         [--as-interval MS] [--as-predict] [--tenants SPEC] [--quota RPS]\n           \
         [--tenant-share F] [--tenant-slo NAME:MS,...]\n           \
         (SPEC = name[:w=N][:p=low|standard|high][:rate=R][:burst=B][:share=F][:slo=MS],...)\n           \
         [--virtual-time] [--trace CURVE] [--trace-file CSV] [--duration S]\n           \
         [--variant V] [--report-out FILE]\n           \
         (CURVE = const:RPS | diurnal:BASE:PEAK:PERIOD[:PHASE] | flash:BASE:SPIKE:AT:RAMP:HOLD)\n           \
         [--faults PLAN] [--retry N] [--breaker] [--hedge-ms MS] [--brownout]\n           \
         (PLAN = site-loss-storm | crash:SITE:POD:AT[:RESTART];straggle:SITE:AT:UNTIL:FACTOR;\n            \
         link:A:B:AT:UNTIL:RTT_FACTOR:LOSS;partition:A:B:AT:HEAL;flap:SITE:AT:RECOVER)\n           \
         (--hedge-ms/--brownout need --virtual-time; crash faults also run threaded)\n  \
         continuum [--config FILE] [--policy min-latency|min-energy|balanced] [--site NAME]\n           \
         [--requests N] [--arrival A] [--models a,b] [--replicas N] [--queue N]\n           \
         [--batch N] [--workers N] [--time-scale F] [--seed N] [--run-seed N]\n           \
         [--fail-site NAME] [--fail-at I] [--scenarios]\n           \
         [--migrate] [--energy-budget W]  (post-drive live migration: forecast-\n            \
         driven, or watt-budgeted with --energy-budget; threaded path only)\n           \
         [--virtual-time] [--scenario diurnal-day|flash-crowd|site-loss-storm|\n            \
         million-user-day|mobile-day]\n           \
         [--trace-file CSV] [--duration S] [--fail-at-s S] [--recover-at-s S]\n           \
         [--faults PLAN] [--retry N] [--hedge-ms MS] [--breaker] [--brownout]\n           \
         [--report-out FILE]\n  \
         apply    MANIFEST [--plan --against PREV] [--from PREV] [--requests N]\n           \
         [--seed N] [--out FILE] [--watch] [--interval-ms MS] [--max-loops N]\n           \
         (declarative deploy: --plan prints the canonical action diff vs the\n            \
         applied manifest and exits 2 on drift; --from deploys PREV, drives\n            \
         traffic, converges to MANIFEST mid-stream and proves re-apply is a\n            \
         no-op; --watch polls the file and re-converges on change)\n  \
         apply    --scenarios [--seed N]  (deterministic convergence verdicts)\n  \
         bench    [--batches 1,2,4,8] [--rates 500,2000,8000] [--requests N] [--models a,b]\n           \
         [--replicas N] [--queue N] [--workers N] [--time-scale F] [--pool N]\n           \
         [--slo MS] [--seed N] [--out FILE] [--fused-only]\n           \
         [--hotpath]  (submit→verdict overhead harness at saturation over\n            \
         zero-work pods; writes only the v8 `hotpath` section; default\n            \
         20000 requests/arm; incompatible with --fused-only)\n  \
         report   <table1|table2|table3|fig3|fig4|fig5|all> [--requests N] [--real N]\n"
    );
}

fn csv_list(s: Option<&str>, default: &[&str]) -> Vec<String> {
    match s {
        Some(v) => v.split(',').map(|x| x.trim().to_string()).collect(),
        None => default.iter().map(|x| x.to_string()).collect(),
    }
}

fn csv_nums<T>(s: Option<&str>, default: &[T]) -> Result<Vec<T>>
where
    T: std::str::FromStr + Clone,
    T::Err: std::error::Error + Send + Sync + 'static,
{
    match s {
        Some(v) => v
            .split(',')
            .map(|x| x.trim().parse().with_context(|| format!("bad list entry {x:?}")))
            .collect(),
        None => Ok(default.to_vec()),
    }
}

/// Build the resilience policy from the shared CLI flags: `--retry N`
/// (bounded retries with jittered backoff), `--hedge-ms MS` (tail
/// hedging; `0` derives the threshold from the service EWMA),
/// `--breaker` (per-pod circuit breakers) and `--brownout` (the
/// failure-rate degradation ladder).  Absent flags leave the matching
/// policy off.
fn resilience_from_flags(flags: &Flags) -> Result<ResilienceConfig> {
    let mut r = ResilienceConfig::default();
    if let Some(v) = flags.get("--retry") {
        let max_retries: u32 = v.parse().with_context(|| format!("bad --retry: {v:?}"))?;
        r.retry = Some(RetryPolicy { max_retries, ..Default::default() });
    }
    if let Some(v) = flags.get("--hedge-ms") {
        let threshold_ms: f64 =
            v.parse().with_context(|| format!("bad --hedge-ms: {v:?}"))?;
        if !(threshold_ms >= 0.0) {
            bail!("--hedge-ms must be >= 0 (0 derives the threshold from the EWMA)");
        }
        r.hedge = Some(HedgePolicy { threshold_ms, ..Default::default() });
    }
    if flags.has("--breaker") {
        r.breaker = Some(BreakerConfig::default());
    }
    if flags.has("--brownout") {
        r.brownout = Some(BrownoutConfig::default());
    }
    Ok(r)
}

/// Parse `--faults` (a canned plan name or inline `;`-separated spec);
/// absent means an empty plan.
fn fault_plan_from_flags(flags: &Flags) -> Result<FaultPlan> {
    match flags.get("--faults") {
        Some(spec) => Ok(FaultPlan::named(spec)?),
        None => Ok(FaultPlan::default()),
    }
}

fn cmd_build(flags: &Flags) -> Result<()> {
    let mut variants = csv_list(flags.get("--variants"), coordinator::VARIANTS);
    if flags.has("--native") {
        variants.extend(coordinator::NATIVE_VARIANTS.iter().map(|s| s.to_string()));
    }
    let opts = GenerateOptions {
        models: csv_list(flags.get("--models"), coordinator::MODELS),
        variants,
        jobs: flags.usize_or("--jobs", GenerateOptions::default().jobs)?,
        force: flags.has("--force"),
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    let rows = coordinator::generate(".", &opts)?;
    let (h, r) = report::fig3(&rows);
    print!("{}", report::render_table(&h, &r));
    println!(
        "\n{} AIF bundles (server+client) in {:.1}s wall",
        rows.len(),
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}

fn cmd_verify(flags: &Flags) -> Result<()> {
    let dir = flags.get("--artifacts").unwrap_or(ARTIFACTS_DIR);
    let engine = Engine::cpu()?;
    let results = coordinator::verify_all(&engine, dir)?;
    let rows: Vec<Vec<String>> = results
        .iter()
        .map(|(id, d)| vec![id.clone(), format!("{d:.3e}"), "OK".into()])
        .collect();
    print!(
        "{}",
        report::render_table(&["AIF", "max |Δ| vs build-time logits", "status"], &rows)
    );
    println!("\n{} artifacts verified on {}", results.len(), engine.platform_name());
    Ok(())
}

fn cmd_serve(flags: &Flags) -> Result<()> {
    let aif = flags.get("--aif").context("--aif <model_variant> required")?;
    let requests = flags.usize_or("--requests", 100)?;
    let arrival = match flags.get("--rps") {
        Some(r) => Arrival::Poisson { rps: r.parse().context("bad --rps")? },
        None => Arrival::ClosedLoop,
    };
    let engine = Engine::cpu()?;
    let art = Arc::new(artifact::Artifact::load(format!("{ARTIFACTS_DIR}/{aif}"))?);
    let server = Arc::new(AifServer::deploy(&engine, &art, Arc::new(ImageClassify))?);
    println!(
        "deployed {} (compile {:.2}s, weights {:.2}s, {} tensors)",
        aif, server.model.compile_time_s, server.model.weight_upload_time_s,
        server.model.num_weights()
    );
    let client = Client::new(Arc::clone(&server));
    let verified = client.verify(&art)?;
    println!("client verification: {verified} fixtures OK");
    let run = client.run(&ClientConfig { requests, arrival, seed: 7 })?;
    let mut svc = run.service_ms.clone();
    let bp = svc.boxplot();
    println!(
        "\n{requests} requests | service*: median {:.2} ms  q1 {:.2}  q3 {:.2} | \
         real compute: mean {:.2} ms | throughput {:.1} rps\n(* simulated {} platform)",
        bp.median,
        bp.q1,
        bp.q3,
        run.real_compute_ms.mean(),
        run.throughput_rps(),
        server.platform().name,
    );
    Ok(())
}

fn cmd_cluster(flags: &Flags) -> Result<()> {
    let mut cluster = match flags.get("--config") {
        Some(path) => Cluster::from_config(&Config::load(path)?)?,
        None => Cluster::new(paper_testbed()),
    };
    let policy = Policy::parse(flags.get("--policy").unwrap_or("min-latency"))?;
    println!("cluster nodes:");
    let (h, r) = report::table2(cluster.nodes());
    print!("{}", report::render_table(&h, &r));
    println!("\napplying Kube-API extension (registers ARM device plugins)…");
    cluster.apply_kube_api_extension();

    let artifacts = artifact::scan(ARTIFACTS_DIR)?;
    let backend = Backend::new(artifacts, policy);
    let engine = Engine::cpu()?;
    let models = match flags.get("--model") {
        Some(m) => vec![m.to_string()],
        None => backend.models().iter().map(|s| s.to_string()).collect(),
    };
    for model in &models {
        let dep = backend.deploy(model, &mut cluster, &engine)?;
        println!(
            "{model}: deployed variant {} on node {} (pod {}, modeled {:.2} ms)",
            dep.decision.variant, dep.decision.node, dep.pod, dep.decision.modeled_ms
        );
    }
    println!("\nrunning pods:");
    for p in cluster.running_pods() {
        println!("  pod {} {} [{}] on {}", p.id, p.aif, p.variant, p.node);
    }
    Ok(())
}

fn cmd_fabric(flags: &Flags) -> Result<()> {
    if flags.has("--virtual-time") {
        return cmd_fabric_des(flags);
    }
    // ── Cluster + backend ───────────────────────────────────────────────
    let mut cluster = match flags.get("--config") {
        Some(path) => Cluster::from_config(&Config::load(path)?)?,
        None => Cluster::new(paper_testbed()),
    };
    cluster.apply_kube_api_extension();
    let policy = Policy::parse(flags.get("--policy").unwrap_or("min-latency"))?;

    let real = flags.has("--real");
    let artifacts = if real {
        artifact::scan(ARTIFACTS_DIR)?
    } else {
        sim::synthetic_catalog()
    };
    let artifacts = match flags.get("--models") {
        Some(ms) => {
            let wanted = csv_list(Some(ms), &[]);
            artifacts
                .into_iter()
                .filter(|a| wanted.iter().any(|m| *m == a.manifest.model))
                .collect()
        }
        None => artifacts,
    };
    if artifacts.is_empty() {
        bail!("no artifacts to place (with --real, run `tf2aif build` first)");
    }
    let mut backend = Backend::new(artifacts, policy);

    let d = FabricConfig::default();
    let autoscale = match flags.get("--autoscale") {
        Some(spec) => {
            let (lo, hi) = spec
                .split_once(':')
                .with_context(|| format!("bad --autoscale {spec:?} (expected MIN:MAX)"))?;
            let min_replicas: usize = lo.parse().with_context(|| format!("bad min {lo:?}"))?;
            let max_replicas: usize = hi.parse().with_context(|| format!("bad max {hi:?}"))?;
            if min_replicas < 1 || min_replicas > max_replicas {
                bail!(
                    "bad --autoscale {spec:?}: need 1 <= MIN <= MAX, \
                     got {min_replicas}:{max_replicas}"
                );
            }
            Some(AutoscaleConfig {
                min_replicas,
                max_replicas,
                interval_ms: flags.usize_or(
                    "--as-interval",
                    AutoscaleConfig::default().interval_ms as usize,
                )? as u64,
                predictive: flags.has("--as-predict"),
                ..Default::default()
            })
        }
        None => None,
    };
    // ── Tenancy: --tenants SPEC, --quota (default token rate), and
    //    --tenant-share (default max queue fraction) ────────────────────
    let default_share = flags.f64_or("--tenant-share", 1.0)?;
    let default_quota = match flags.get("--quota") {
        Some(v) => {
            let q: f64 = v.parse().with_context(|| format!("bad --quota: {v:?}"))?;
            if !(q > 0.0) {
                bail!("--quota must be positive (a zero quota could never admit a request)");
            }
            Some(q)
        }
        None => None,
    };
    let mut tenants: Vec<TenantSpec> = match flags.get("--tenants") {
        Some(spec) => parse_tenant_specs(spec, default_quota, default_share)
            .map_err(anyhow::Error::new)?,
        None => match default_quota {
            // --quota without --tenants rate-limits the default tenant.
            Some(q) => {
                let mut t = TenantSpec::new(tf2aif::fabric::DEFAULT_TENANT);
                t.rate_rps = Some(q);
                t.burst = q.ceil().max(1.0);
                t.max_queue_share = default_share;
                vec![t]
            }
            None => Vec::new(),
        },
    };
    if tenants.is_empty() && flags.get("--tenant-share").is_some() {
        bail!("--tenant-share has no effect without --tenants or --quota");
    }
    if let Some(slos) = flags.get("--tenant-slo") {
        if tenants.is_empty() {
            bail!("--tenant-slo needs --tenants (or --quota) to define the tenants first");
        }
        apply_tenant_slos(&mut tenants, slos).map_err(anyhow::Error::new)?;
    }
    let multi_tenant = !tenants.is_empty();
    // Offered-load split for the drive: the configured tenants only
    // (the implicit `default` tenant is a home for anonymous traffic,
    // not a workload source), weighted by their drain weights.
    let mix_entries: Vec<(String, u32)> =
        tenants.iter().map(|t| (t.id.clone(), t.weight)).collect();

    // Hedging and brownout are virtual-time policies; on the threaded
    // path they would silently do nothing, which this CLI treats as an
    // error (same convention as the DES no-effect flags).
    for flag in ["--hedge-ms", "--brownout"] {
        if flags.has(flag) {
            bail!("{flag} needs --virtual-time (hedging/brownout run on the virtual clock)");
        }
    }
    let cfg = FabricConfig {
        queue_capacity: flags.usize_or("--queue", d.queue_capacity)?,
        max_batch: flags.usize_or("--batch", d.max_batch)?,
        adaptive: flags.has("--adaptive"),
        min_batch: flags.usize_or("--min-batch", d.min_batch)?,
        slo_p99_ms: flags.f64_or("--slo", d.slo_p99_ms)?,
        batch_linger_ms: flags.f64_or("--linger", d.batch_linger_ms)?,
        workers: flags.usize_or("--workers", d.workers)?,
        replicas_per_model: flags.usize_or("--replicas", d.replicas_per_model)?,
        time_scale: flags.f64_or("--time-scale", d.time_scale)?,
        seed: flags.usize_or("--seed", d.seed as usize)? as u64,
        fused: !flags.has("--per-item"),
        dedup: !flags.has("--no-dedup"),
        cache_capacity: flags.usize_or("--cache", d.cache_capacity)?,
        cache_ttl_ms: flags.usize_or("--cache-ttl", d.cache_ttl_ms as usize)? as u64,
        autoscale,
        tenants,
        resilience: resilience_from_flags(flags)?,
        ..Default::default()
    };
    let fault_plan = fault_plan_from_flags(flags)?;

    // ── Place + spawn the fleet ─────────────────────────────────────────
    let fabric = if real {
        let engine = Engine::cpu()?;
        Fabric::place_real(&backend, cluster, engine, &cfg)?
    } else {
        Fabric::place_sim(&backend, cluster, &cfg, None)?
    };
    // Close the loop: placement scoring now sees fabric measurements.
    backend.feedback = Some(fabric.feedback());

    println!(
        "fabric: {} pods over {} nodes ({} mode, queue bound {}, batch {} [{}], \
         {} worker(s)/pod, dedup {}, cache {}, autoscale {})",
        fabric.plans().len(),
        fabric.nodes_spanned().len(),
        if real { "real PJRT" } else { "simulated" },
        cfg.queue_capacity,
        if cfg.adaptive {
            format!("adaptive ≤{} (SLO {:.0} ms)", cfg.max_batch, cfg.slo_p99_ms)
        } else {
            cfg.max_batch.to_string()
        },
        if cfg.fused { "fused" } else { "per-item" },
        cfg.workers,
        if cfg.dedup { "on" } else { "off" },
        if cfg.cache_capacity > 0 {
            format!("{} entries / {} ms TTL", cfg.cache_capacity, cfg.cache_ttl_ms)
        } else {
            "off".to_string()
        },
        match &cfg.autoscale {
            Some(a) => format!("{}..{} replicas", a.min_replicas, a.max_replicas),
            None => "off".to_string(),
        },
    );
    for p in fabric.plans() {
        println!(
            "  pod {:<3} {:<20} [{:<6}] on {:<4} (modeled {:.2} ms)",
            p.pod_id, p.aif, p.variant, p.node, p.modeled_ms
        );
    }

    // ── Fault plan (threaded path replays pod crashes) ──────────────────
    if !fault_plan.is_empty() {
        let crashes =
            fault_plan.faults.iter().filter(|f| matches!(f, Fault::PodCrash { .. })).count();
        println!(
            "\nfault plan {:?}: {} fault(s); {} pod crash(es) scheduled (latency/link/site \
             faults need --virtual-time and are skipped here)",
            fault_plan.name,
            fault_plan.faults.len(),
            crashes,
        );
        drop(fabric.schedule_faults(&fault_plan, cfg.time_scale));
    }

    // ── Drive the workload ──────────────────────────────────────────────
    let requests = flags.usize_or("--requests", 1000)?;
    let arrival = Arrival::parse(flags.get("--arrival").unwrap_or("poisson:500"))?;
    let seed = flags.usize_or("--run-seed", 7)? as u64;
    println!("\nrouting {requests} requests ({arrival:?}) across the fleet…");
    let run = if multi_tenant {
        let mix = TenantMix::new(&mix_entries)?;
        fabric.run_tenants(requests, arrival, seed, &mix)?
    } else {
        fabric.run(requests, arrival, seed)?
    };

    println!(
        "\nrouted {} | completed {} | shed {} | deduped {} | failed {} | wall {:.2}s | {:.1} rps",
        run.submitted,
        run.completed,
        run.shed,
        fabric.dedup_hits(),
        run.failed,
        run.wall_s,
        run.throughput_rps()
    );
    if !run.e2e_ms.is_empty() {
        let bp = run.e2e_ms.clone().boxplot();
        println!(
            "e2e (queue+service): median {:.2} ms  q3 {:.2}  max {:.2}  (* simulated platforms)",
            bp.median, bp.q3, bp.max
        );
    }

    println!("\nper-pod:");
    let (h, rows) = report::fabric_pods(&fabric.pod_reports(run.wall_s));
    print!("{}", report::render_table(&h, &rows));
    report::write_csv("reports/fabric_pods.csv", &h, &rows)?;

    println!("\nfleet:");
    let (h, rows) = report::fabric_fleet(&fabric.fleet_report(run.wall_s));
    print!("{}", report::render_table(&h, &rows));
    report::write_csv("reports/fabric_fleet.csv", &h, &rows)?;

    if multi_tenant {
        println!("\nper-tenant:");
        let (h, rows) = report::fabric_tenants(&fabric.tenant_reports());
        print!("{}", report::render_table(&h, &rows));
        report::write_csv("reports/fabric_tenants.csv", &h, &rows)?;
    }

    let events = fabric.scale_events();
    if !events.is_empty() {
        println!("\nreplica timeline (autoscaler):");
        let (h, rows) = report::fabric_scale_events(&events);
        print!("{}", report::render_table(&h, &rows));
    }
    if let Some(err) = fabric.last_scale_error() {
        println!("\nautoscaler: last pod-spawn failure: {err}");
    }
    if let Some(stats) = fabric.cache_stats() {
        println!(
            "\nresponse cache: {} hits, {} misses, {} evicted, {} expired, {} live entries",
            stats.hits, stats.misses, stats.evicted, stats.expired, stats.entries
        );
    }
    let targets = fabric.batch_targets();
    if !targets.is_empty() {
        println!("\nadaptive batch targets (pod → drain size):");
        for (key, target) in targets {
            println!("  {key:<20} {target}");
        }
    }

    println!("\nmeasured feedback (model_variant@node → EWMA service / queue-wait ms):");
    for (key, fb) in fabric.feedback().all() {
        println!(
            "  {key:<14} {:.2} / {:.2} ms over {} obs",
            fb.ewma_service_ms, fb.ewma_queue_wait_ms, fb.observations
        );
    }
    // Demonstrate the adapted placement scores.
    if let Some(model) = backend.models().first().map(|m| m.to_string()) {
        if let Ok(d) = fabric.with_cluster(|cluster| backend.select(&model, cluster)) {
            println!(
                "\nre-ranked {model}: {} on {} (modeled {:.2} ms → estimated {:.2} ms)",
                d.variant, d.node, d.modeled_ms, d.estimated_ms
            );
        }
    }
    fabric.shutdown();
    Ok(())
}

// ── Virtual-time (DES) CLI paths ────────────────────────────────────────

/// Threaded-path flags the DES would silently ignore are errors,
/// matching this CLI's no-effect-flag convention.
fn reject_des_no_effect(flags: &Flags, no_effect: &[&str]) -> Result<()> {
    for flag in no_effect {
        if flags.has(flag) {
            bail!(
                "{flag} has no effect with --virtual-time (the DES replays \
                 open-loop virtual traffic on a virtual clock; see docs/CLI.md)"
            );
        }
    }
    Ok(())
}

/// Print the human summary of a DES run and optionally persist the
/// canonical report.  Wall-clock figures are printed but never written
/// into the report itself, which stays bit-reproducible.
fn print_des_report(report: &DesReport, wall_s: f64, report_out: Option<&str>) -> Result<()> {
    println!(
        "\nvirtual time: {:.1}s simulated in {:.2}s wall ({} events, {:.0} events/s)",
        report.virtual_end_ms / 1e3,
        wall_s,
        report.events,
        report.events as f64 / wall_s.max(1e-9),
    );
    println!(
        "requests: {} submitted = {} completed + {} cached + {} shed + {} quota-shed \
         + {} failed (conservation: {}; {} retries)",
        report.submitted,
        report.completed,
        report.cache_hits,
        report.shed,
        report.quota_shed,
        report.failed,
        yn(report.conservation_holds()),
        report.retries,
    );
    println!(
        "latency (e2e ms): p50 {:.2}  p99 {:.2}  mean {:.2}  max {:.2}   \
         spilled {}  rerouted {}",
        report.p50_ms, report.p99_ms, report.mean_ms, report.max_ms, report.spilled, report.rerouted,
    );
    if report.faults_injected > 0
        || report.hedges_launched > 0
        || report.breaker_trips > 0
        || report.brownout_ms > 0.0
    {
        println!(
            "resilience: {} fault(s) injected | hedges {} launched / {} won / {} lost | \
             breaker trips {} (open at end: {}) | brownout {:.0} ms",
            report.faults_injected,
            report.hedges_launched,
            report.hedges_won,
            report.hedges_lost,
            report.breaker_trips,
            report.breakers_open_end,
            report.brownout_ms,
        );
    }
    println!(
        "\n{:<10} {:>5} {:>9} {:>9} {:>7} {:>6} {:>6} {:>9} {:>9} {:>5} {:>7} {:>8} {:>4} {:>8}",
        "site", "up", "submitted", "completed", "cached", "shed", "failed", "served",
        "spill-in", "pods", "p50ms", "p99ms", "brk", "scale+/-",
    );
    for s in &report.sites {
        println!(
            "{:<10} {:>5} {:>9} {:>9} {:>7} {:>6} {:>6} {:>9} {:>9} {:>5} {:>7.2} {:>8.2} \
             {:>4} {:>5}/{}",
            s.name,
            yn(s.up),
            s.submitted,
            s.completed,
            s.cache_hits,
            s.shed + s.quota_shed,
            s.failed,
            s.served_here,
            s.spillover_in,
            s.pods_end,
            s.p50_ms,
            s.p99_ms,
            s.breaker_trips,
            s.scale_ups,
            s.scale_downs,
        );
    }
    if let Some(path) = report_out {
        std::fs::write(path, report.canonical_json())
            .with_context(|| format!("writing {path}"))?;
        println!("\ncanonical report written to {path}");
    }
    Ok(())
}

/// `tf2aif fabric --virtual-time`: one site on the event heap — the
/// fabric's batch/linger/quota/cache/autoscale controls replayed
/// deterministically against an open-loop rate curve or a CSV trace
/// (site column `fabric`).
fn cmd_fabric_des(flags: &Flags) -> Result<()> {
    reject_des_no_effect(
        flags,
        &[
            "--real",
            "--requests",
            "--arrival",
            "--workers",
            "--time-scale",
            "--run-seed",
            "--policy",
            "--config",
            "--cache",
            "--per-item",
            "--no-dedup",
            "--as-predict",
            "--tenants",
            "--tenant-share",
            "--tenant-slo",
        ],
    )?;
    let wanted = csv_list(flags.get("--models"), &[]);
    let wanted: Vec<&str> = wanted.iter().map(String::as_str).collect();
    let catalog = sim::synthetic_catalog_for(&wanted);
    let mut models: Vec<DesModel> = Vec::new();
    for a in &catalog {
        if !models.iter().any(|m| m.name == a.manifest.model) {
            models.push(DesModel { name: a.manifest.model.clone(), gflops: a.manifest.gflops });
        }
    }
    if models.is_empty() {
        bail!("no catalog models match --models");
    }

    let da = DesAutoscale::default();
    let autoscale = match flags.get("--autoscale") {
        Some(spec) => {
            let (lo, hi) = spec
                .split_once(':')
                .with_context(|| format!("bad --autoscale {spec:?} (expected MIN:MAX)"))?;
            let min_pods: usize = lo.parse().with_context(|| format!("bad min {lo:?}"))?;
            let max_pods: usize = hi.parse().with_context(|| format!("bad max {hi:?}"))?;
            if min_pods < 1 || min_pods > max_pods {
                bail!("bad --autoscale {spec:?}: need 1 <= MIN <= MAX, got {min_pods}:{max_pods}");
            }
            Some(DesAutoscale {
                min_pods,
                max_pods,
                interval_ms: flags.f64_or("--as-interval", da.interval_ms)?,
                ..Default::default()
            })
        }
        None => None,
    };

    let dc = DesConfig::default();
    let quota_rps = flags.f64_or("--quota", dc.quota_rps)?;
    let cfg = DesConfig {
        queue_capacity: flags.usize_or("--queue", dc.queue_capacity)?,
        max_batch: flags.usize_or("--batch", dc.max_batch)?,
        min_batch: flags.usize_or("--min-batch", dc.min_batch)?,
        adaptive: flags.has("--adaptive"),
        slo_p99_ms: flags.f64_or("--slo", dc.slo_p99_ms)?,
        batch_linger_ms: flags.f64_or("--linger", dc.batch_linger_ms)?,
        quota_rps,
        quota_burst: quota_rps.ceil().max(1.0),
        cache_ttl_ms: flags.f64_or("--cache-ttl", dc.cache_ttl_ms)?,
        cohorts: flags.usize_or("--cohorts", dc.cohorts)?,
        autoscale,
        resilience: resilience_from_flags(flags)?,
        seed: flags.usize_or("--seed", dc.seed as usize)? as u64,
    };

    let horizon_s = flags.f64_or("--duration", 60.0)?;
    let trace = match flags.get("--trace-file") {
        Some(path) => Some(read_trace_csv(path)?),
        None => None,
    };
    let arrivals = match trace {
        Some(_) => {
            if flags.get("--trace").is_some() {
                bail!("--trace has no effect with --trace-file (the CSV replaces the curve)");
            }
            None
        }
        None => Some(RateCurve::parse(flags.get("--trace").unwrap_or("const:50"))?),
    };
    let variant = flags.get("--variant").unwrap_or("AGX").to_string();
    let sc = DesScenario {
        name: "fabric-cli".to_string(),
        horizon_s,
        models,
        sites: vec![DesSite {
            name: "fabric".to_string(),
            tier: "edge".to_string(),
            variant,
            pods: flags.usize_or("--replicas", 1)?,
            arrivals,
            mix: None,
        }],
        rtt_ms: vec![vec![0.0]],
        trace,
        drills: Vec::new(),
        handovers: Vec::new(),
        faults: fault_plan_from_flags(flags)?,
        cfg,
    };
    println!(
        "fabric (virtual time): {} model(s) on {} ({} pod(s)), horizon {:.0}s, seed {}{}",
        sc.models.len(),
        sc.sites[0].variant,
        sc.sites[0].pods,
        sc.horizon_s,
        sc.cfg.seed,
        if sc.faults.is_empty() {
            String::new()
        } else {
            format!(", fault plan {:?} ({} fault(s))", sc.faults.name, sc.faults.faults.len())
        },
    );
    let t0 = Instant::now();
    let report = run_des(&sc)?;
    print_des_report(&report, t0.elapsed().as_secs_f64(), flags.get("--report-out"))
}

/// `tf2aif continuum --virtual-time`: a canned multi-site scenario on
/// the built-in 3-site testbed, replayed on the event heap.  The
/// default scenario is the million-user diurnal day the CI determinism
/// gate drives.
fn cmd_continuum_des(flags: &Flags) -> Result<()> {
    reject_des_no_effect(
        flags,
        &[
            "--scenarios",
            "--requests",
            "--arrival",
            "--run-seed",
            "--fail-at",
            "--policy",
            "--site",
            "--config",
            "--workers",
            "--time-scale",
            "--replicas",
            "--models",
            "--migrate",
            "--energy-budget",
        ],
    )?;
    let seed = flags.usize_or("--seed", DesConfig::default().seed as usize)? as u64;
    let name = flags.get("--scenario").unwrap_or("million-user-day");
    let mut sc = tf2aif::continuum::des::canned(name, seed)?;
    sc.cfg.queue_capacity = flags.usize_or("--queue", sc.cfg.queue_capacity)?;
    sc.cfg.max_batch = flags.usize_or("--batch", sc.cfg.max_batch)?;
    sc.cfg.batch_linger_ms = flags.f64_or("--linger", sc.cfg.batch_linger_ms)?;
    sc.horizon_s = flags.f64_or("--duration", sc.horizon_s)?;
    // Resilience flags override the scenario's own policy per field, so
    // e.g. `--retry 4` on the storm keeps its hedging/breaker defaults.
    let r = resilience_from_flags(flags)?;
    if r.retry.is_some() {
        sc.cfg.resilience.retry = r.retry;
    }
    if r.hedge.is_some() {
        sc.cfg.resilience.hedge = r.hedge;
    }
    if r.breaker.is_some() {
        sc.cfg.resilience.breaker = r.breaker;
    }
    if r.brownout.is_some() {
        sc.cfg.resilience.brownout = r.brownout;
    }
    if let Some(spec) = flags.get("--faults") {
        sc.faults = FaultPlan::named(spec)?;
    }
    if let Some(path) = flags.get("--trace-file") {
        sc.trace = Some(read_trace_csv(path)?);
        for site in &mut sc.sites {
            site.arrivals = None;
        }
    }
    match flags.get("--fail-site") {
        Some(site) => {
            let at_s = flags.f64_or("--fail-at-s", sc.horizon_s * 0.5)?;
            sc.drills.push(Drill::FailSite { at_s, site: site.to_string() });
            if let Some(rec) = flags.get("--recover-at-s") {
                let at_s: f64 = rec.parse().with_context(|| format!("bad --recover-at-s {rec:?}"))?;
                sc.drills.push(Drill::RecoverSite { at_s, site: site.to_string() });
            }
        }
        None => {
            if flags.get("--fail-at-s").is_some() || flags.get("--recover-at-s").is_some() {
                bail!("--fail-at-s/--recover-at-s need --fail-site");
            }
        }
    }
    println!(
        "continuum (virtual time): scenario {:?}, {} site(s), horizon {:.0}s, seed {}{}",
        sc.name,
        sc.sites.len(),
        sc.horizon_s,
        seed,
        if sc.faults.is_empty() {
            String::new()
        } else {
            format!(", fault plan {:?} ({} fault(s))", sc.faults.name, sc.faults.faults.len())
        },
    );
    let t0 = Instant::now();
    let report = run_des(&sc)?;
    print_des_report(&report, t0.elapsed().as_secs_f64(), flags.get("--report-out"))
}

fn cmd_continuum(flags: &Flags) -> Result<()> {
    if flags.has("--virtual-time") {
        return cmd_continuum_des(flags);
    }
    // Hedging, brownout and multi-fault plans are virtual-time features
    // on the continuum path; rejecting them beats silently ignoring.
    for flag in ["--hedge-ms", "--brownout", "--faults"] {
        if flags.has(flag) {
            bail!("{flag} needs --virtual-time on the continuum path");
        }
    }
    let migrate = flags.has("--migrate");
    let energy_budget_w = flags
        .get("--energy-budget")
        .map(|v| v.parse::<f64>().with_context(|| format!("bad --energy-budget {v:?}")))
        .transpose()?;
    if energy_budget_w.is_some() && !migrate {
        bail!("--energy-budget needs --migrate");
    }
    let d = FabricConfig::default();
    let cfg = FabricConfig {
        queue_capacity: flags.usize_or("--queue", d.queue_capacity)?,
        max_batch: flags.usize_or("--batch", d.max_batch)?,
        workers: flags.usize_or("--workers", d.workers)?,
        replicas_per_model: flags.usize_or("--replicas", d.replicas_per_model)?,
        time_scale: flags.f64_or("--time-scale", d.time_scale)?,
        seed: flags.usize_or("--seed", d.seed as usize)? as u64,
        resilience: resilience_from_flags(flags)?,
        // Live migration needs the autoscaler's spawn/retire path (ticked
        // explicitly, never by a thread) plus a response cache so warm
        // state has something to carry.
        autoscale: if migrate {
            Some(tf2aif::fabric::AutoscaleConfig {
                interval_ms: 0,
                predictive: true,
                ..Default::default()
            })
        } else {
            None
        },
        cache_capacity: if migrate { 256 } else { d.cache_capacity },
        cache_ttl_ms: if migrate { 60_000 } else { d.cache_ttl_ms },
        ..Default::default()
    };
    if flags.has("--scenarios") {
        // The scenario suite runs the built-in testbed under fixed
        // policies; flags it would silently ignore are errors, matching
        // this CLI's no-effect-flag convention.
        if migrate {
            bail!(
                "--migrate has no effect with --scenarios (the migration drill is its \
                 own suite: drop --scenarios, or see `tf2aif bench`'s migration section)"
            );
        }
        for flag in [
            "--config",
            "--policy",
            "--site",
            "--models",
            "--fail-site",
            "--fail-at",
            "--requests",
            "--arrival",
            "--run-seed",
        ] {
            if flags.get(flag).is_some() {
                bail!(
                    "{flag} has no effect with --scenarios (the scenario suite runs \
                     the built-in 3-site testbed under fixed policies)"
                );
            }
        }
        println!("running the deterministic continuum scenarios (3-site testbed)…");
        let v = continuum::run_scenarios(cfg.seed);
        println!(
            "spillover recovers on the next-ranked site: {} ({} spilled, {} completed there)\n\
             mid-stream site loss drops nothing: {} ({} models moved)\n\
             energy-policy tradeoff visible: {} (min-energy {:.4} J/req vs min-latency {:.4}; \
             latency {:.2} → {:.2} ms)",
            yn(v.spillover_recovers),
            v.spilled,
            v.spill_completed,
            yn(v.replan_no_drop),
            v.replan_moves,
            yn(v.energy_policy_tradeoff),
            v.min_energy_energy_j,
            v.min_latency_energy_j,
            v.min_latency_ms,
            v.min_energy_ms,
        );
        return Ok(());
    }
    let topology = match flags.get("--config") {
        Some(path) => Topology::from_config(&Config::load(path)?)?,
        None => continuum::continuum_testbed(),
    };
    let policy = PlanPolicy::parse(flags.get("--policy").unwrap_or("min-latency"))?;
    // Demand originates at the lowest tier by default (far-edge first).
    let demand_site = match flags.get("--site") {
        Some(name) => name.to_string(),
        None => topology
            .sites()
            .iter()
            .max_by_key(|s| s.tier)
            .map(|s| s.name.clone())
            .expect("validated topology has sites"),
    };
    let catalog = match flags.get("--models") {
        Some(ms) => {
            let wanted = csv_list(Some(ms), &[]);
            sim::synthetic_catalog()
                .into_iter()
                .filter(|a| wanted.iter().any(|m| *m == a.manifest.model))
                .collect()
        }
        None => sim::synthetic_catalog(),
    };
    if catalog.is_empty() {
        bail!("no catalog models match --models");
    }
    let mut orch = ContinuumOrchestrator::deploy_sim(
        topology,
        catalog,
        policy,
        &demand_site,
        &cfg,
        &std::collections::BTreeMap::new(),
    )?;
    println!(
        "continuum: {} sites, policy {policy}, demand at {demand_site} \
         (modeled plan mean: {:.2} ms e2e, {:.4} J/request)\n\nplan:",
        orch.active_sites().len(),
        orch.plan().mean_latency_ms(),
        orch.plan().mean_energy_j(),
    );
    let (h, rows) = report::continuum_plan(orch.plan());
    print!("{}", report::render_table(&h, &rows));
    report::write_csv("reports/continuum_plan.csv", &h, &rows)?;

    let requests = flags.usize_or("--requests", 1000)?;
    let arrival = Arrival::parse(flags.get("--arrival").unwrap_or("poisson:500"))?;
    let run_seed = flags.usize_or("--run-seed", 7)? as u64;
    let entries: Vec<(String, u32)> =
        orch.plan().models().iter().map(|m| (m.to_string(), 1)).collect();
    let mix = TenantMix::new(&entries)?;
    let fail = flags
        .get("--fail-site")
        .map(|site| Ok::<_, anyhow::Error>((flags.usize_or("--fail-at", requests / 2)?, site)))
        .transpose()?;
    if fail.is_none() && flags.get("--fail-at").is_some() {
        bail!("--fail-at has no effect without --fail-site");
    }
    match &fail {
        Some((at, site)) => println!(
            "\nrouting {requests} requests ({arrival:?}); killing site {site:?} before \
             request {at}…"
        ),
        None => println!("\nrouting {requests} requests ({arrival:?})…"),
    }
    let run = orch.run(requests, arrival, run_seed, &mix, fail)?;
    println!(
        "\nrouted {} | completed {} | shed {} | failed {} | spilled {} (completed {}) | \
         wall {:.2}s",
        run.submitted,
        run.completed,
        run.shed,
        run.failed,
        run.spilled,
        run.spill_completed,
        run.wall_s,
    );
    if !run.e2e_ms.is_empty() {
        let bp = run.e2e_ms.clone().boxplot();
        println!(
            "e2e (link+queue+service): median {:.2} ms  q3 {:.2}  max {:.2}  \
             (* simulated platforms)",
            bp.median, bp.q3, bp.max
        );
    }
    println!("\nper-site:");
    let (h, rows) = report::continuum_sites(&run.per_site);
    print!("{}", report::render_table(&h, &rows));
    report::write_csv("reports/continuum_sites.csv", &h, &rows)?;
    for ev in orch.replans() {
        println!("\nreplan ({}): {} model(s) moved", ev.reason, ev.moved.len());
        for (model, from, to) in &ev.moved {
            println!("  {model}: {from} → {to}");
        }
        if !ev.stranded.is_empty() {
            println!(
                "  WARNING: no surviving fabric hosts {:?} — that demand will shed",
                ev.stranded
            );
        }
    }
    if migrate {
        let reports = match energy_budget_w {
            Some(w) => {
                println!("\nlive migration (energy budget {w:.1} W per site):");
                orch.energy_budget_migrations(w)
            }
            None => {
                println!("\nlive migration (arrival-rate forecast, floor 1.0 rps):");
                orch.forecast_migrations(1.0)
            }
        };
        if reports.is_empty() {
            println!("  no model qualified for migration (policy thresholds not met)");
        }
        for r in &reports {
            println!(
                "  {}: {} → {} ({}) — {} cache entr{} carried, {} feedback key(s) \
                 seeded, target spawn {}, {} source replica(s) retired",
                r.model,
                r.from,
                r.to,
                r.trigger,
                r.cache_entries_moved,
                if r.cache_entries_moved == 1 { "y" } else { "ies" },
                r.feedback_keys_seeded,
                yn(r.replica_spawned),
                r.replicas_retired,
            );
        }
    }
    orch.shutdown();
    Ok(())
}

fn cmd_apply(flags: &Flags) -> Result<()> {
    if flags.has("--scenarios") {
        for key in
            ["--plan", "--against", "--from", "--watch", "--out", "--requests", "--interval-ms", "--max-loops"]
        {
            if flags.has(key) {
                bail!("{key} has no effect with --scenarios");
            }
        }
        let seed = flags.usize_or("--seed", 0xA11)? as u64;
        let v = run_manifest_scenarios(seed)?;
        println!("manifest convergence scenarios (seed {seed}):");
        println!("  roundtrip_stable   {}", yn(v.roundtrip_stable));
        println!("  plan_matches       {} ({} actions)", yn(v.plan_matches), v.plan_actions);
        println!("  quota_edit_live    {}", yn(v.quota_edit_live));
        println!("  converge_accounted {}", yn(v.converge_accounted));
        println!("  no_lost_admitted   {}", yn(v.no_lost_admitted));
        println!("  reapply_noop       {}", yn(v.reapply_noop));
        println!("  generation_tracks  {}", yn(v.generation_tracks));
        let all = v.roundtrip_stable
            && v.plan_matches
            && v.quota_edit_live
            && v.converge_accounted
            && v.no_lost_admitted
            && v.reapply_noop
            && v.generation_tracks;
        if !all {
            bail!("manifest convergence scenarios failed: {v:?}");
        }
        return Ok(());
    }

    let path = match flags.args.first() {
        Some(p) if !p.starts_with("--") => p.as_str(),
        _ => bail!("apply needs a manifest path first: tf2aif apply MANIFEST [flags]"),
    };

    if flags.has("--plan") {
        let Some(prev_path) = flags.get("--against") else {
            bail!("--plan needs --against PREV (the manifest currently applied)");
        };
        for key in ["--from", "--watch", "--requests", "--seed", "--interval-ms", "--max-loops"] {
            if flags.has(key) {
                bail!("{key} has no effect with --plan");
            }
        }
        let desired = DeploymentManifest::load(path)?;
        let applied = DeploymentManifest::load(prev_path)?;
        let plan = diff(&applied, &desired);
        // Stdout is the plan and nothing else, so CI can `cmp` it
        // against a checked-in golden byte-for-byte.
        println!("{}", render_json(&plan.to_json()));
        if let Some(out) = flags.get("--out") {
            std::fs::write(out, format!("{}\n", render_json(&plan.to_json())))
                .with_context(|| format!("writing {out}"))?;
        }
        if !plan.is_noop() {
            // Drift is not an error, but it is not convergence either:
            // exit 2 (terraform-plan style) so scripts can branch on it.
            std::process::exit(2);
        }
        return Ok(());
    }
    if flags.has("--against") {
        bail!("--against has no effect without --plan");
    }
    if !flags.has("--watch") {
        for key in ["--interval-ms", "--max-loops"] {
            if flags.has(key) {
                bail!("{key} has no effect without --watch");
            }
        }
    }

    let desired = DeploymentManifest::load(path)?;
    let seed = flags.usize_or("--seed", 0xF1E)? as u64;
    let requests = flags.usize_or("--requests", 200)?;
    let (start, plan): (DeploymentManifest, Option<ConvergencePlan>) =
        match flags.get("--from") {
            Some(prev_path) => {
                let prev = DeploymentManifest::load(prev_path)?;
                let plan = diff(&prev, &desired);
                (prev, Some(plan))
            }
            None => (desired.clone(), None),
        };

    println!(
        "deploying generation {} ({} site(s), objective {}, hash {})…",
        start.version,
        start.topology.sites().len(),
        start.objective,
        &content_hash(&start)[..12],
    );
    let mut orch = deploy_manifest_sim(&start, seed)?;
    // Lane sets are fixed at spawn, so traffic rotates over the
    // *deployed* manifest's tenants (anonymous when it declares none).
    let tenant_ids: Vec<String> = start.tenants.iter().map(|t| t.id.clone()).collect();
    let mut pending = Vec::new();
    let mut total = DrivePhase::default();
    let pre: DrivePhase;
    let mut post: Option<DrivePhase> = None;
    let mut apply_report: Option<ApplyReport> = None;

    match &plan {
        Some(plan) => {
            let first = requests / 2;
            println!("driving {first} request(s) under generation {}…", start.version);
            pre = drive(&mut orch, first, seed ^ 0xA, &tenant_ids, &mut pending)?;
            let in_flight = pending.len();
            println!(
                "\nconverging to generation {} ({} action(s), {in_flight} admitted \
                 request(s) in flight):",
                desired.version,
                plan.actions.len()
            );
            let rep = reconcile(&mut orch, plan)?;
            print_apply(&rep);
            apply_report = Some(rep);
            let second = requests - first;
            println!("\ndriving {second} request(s) under generation {}…", desired.version);
            post = Some(drive(&mut orch, second, seed ^ 0xB, &tenant_ids, &mut pending)?);
        }
        None => {
            println!("driving {requests} request(s)…");
            pre = drive(&mut orch, requests, seed ^ 0xA, &tenant_ids, &mut pending)?;
        }
    }
    total.absorb(&pre);
    if let Some(p) = &post {
        total.absorb(p);
    }
    settle(&mut pending, &mut total);

    // Re-applying the manifest that is now live must be a proven no-op:
    // an empty diff, and a reconcile pass that mutates nothing.
    let replan = diff(&desired, &desired);
    let reapply = reconcile(&mut orch, &replan)?;
    let reapply_noop = replan.is_noop() && reapply.is_noop();
    let generation = orch.applied_generation();

    println!(
        "\nsubmitted {} | completed {} | shed {} | failed {} | conservation {} | \
         re-apply no-op {} | generation {generation}",
        total.submitted,
        total.completed,
        total.shed,
        total.failed,
        yn(total.fully_accounted()),
        yn(reapply_noop),
    );

    let phase_json = |p: &DrivePhase| {
        json::obj(vec![
            ("completed", json::n(p.completed as f64)),
            ("failed", json::n(p.failed as f64)),
            ("shed", json::n(p.shed as f64)),
            ("submitted", json::n(p.submitted as f64)),
        ])
    };
    let report = json::obj(vec![
        ("applied_generation", json::n(generation as f64)),
        ("apply", apply_report.as_ref().map_or(Json::Null, ApplyReport::to_json)),
        ("fully_accounted", Json::Bool(total.fully_accounted())),
        ("manifest_hash", json::s(content_hash(&desired))),
        (
            "phases",
            json::obj(vec![
                ("post", post.as_ref().map_or(Json::Null, &phase_json)),
                ("pre", phase_json(&pre)),
            ]),
        ),
        ("plan", plan.as_ref().map_or(Json::Null, ConvergencePlan::to_json)),
        ("reapply_noop", Json::Bool(reapply_noop)),
        ("totals", phase_json(&total)),
    ]);
    if let Some(out) = flags.get("--out") {
        std::fs::write(out, format!("{}\n", report.to_string()))
            .with_context(|| format!("writing {out}"))?;
        println!("report written to {out}");
    }

    if flags.has("--watch") {
        watch_loop(flags, path, desired, &mut orch)?;
    }
    orch.shutdown();
    if !total.fully_accounted() {
        bail!(
            "conservation identity violated: {} submitted != {} completed + {} shed + \
             {} failed",
            total.submitted,
            total.completed,
            total.shed,
            total.failed,
        );
    }
    Ok(())
}

fn print_apply(rep: &ApplyReport) {
    for line in &rep.applied {
        println!("  applied  {line}");
    }
    for line in &rep.deferred {
        println!("  deferred {line}");
    }
    for line in &rep.rejected {
        println!("  rejected {line}");
    }
    if rep.is_noop() && rep.deferred.is_empty() && rep.rejected.is_empty() {
        println!("  (no-op)");
    }
}

/// `tf2aif apply --watch`: poll the manifest file and re-converge the
/// live orchestrator whenever its *meaning* changes.  Three cheap gates
/// before any work: mtime, raw-byte sha256, then the canonical content
/// hash (so formatting-only edits converge nothing).
fn watch_loop(
    flags: &Flags,
    path: &str,
    mut applied: DeploymentManifest,
    orch: &mut ContinuumOrchestrator,
) -> Result<()> {
    let interval = flags.usize_or("--interval-ms", 500)? as u64;
    let max_loops = flags.usize_or("--max-loops", 0)?;
    println!(
        "\nwatching {path} (every {interval} ms{})…",
        if max_loops > 0 { format!(", {max_loops} poll(s)") } else { ", ctrl-c to stop".into() }
    );
    let mut hash = content_hash(&applied);
    let mut raw_hash = std::fs::read(path).map(|b| sha256_hex(&b)).unwrap_or_default();
    let mut mtime = std::fs::metadata(path).and_then(|m| m.modified()).ok();
    let mut polls = 0usize;
    loop {
        if max_loops > 0 && polls >= max_loops {
            return Ok(());
        }
        polls += 1;
        std::thread::sleep(Duration::from_millis(interval));
        let now = std::fs::metadata(path).and_then(|m| m.modified()).ok();
        if now == mtime {
            continue;
        }
        mtime = now;
        let bytes = std::fs::read(path).with_context(|| format!("re-reading {path}"))?;
        let new_raw = sha256_hex(&bytes);
        if new_raw == raw_hash {
            continue;
        }
        raw_hash = new_raw;
        let next = match DeploymentManifest::parse(&String::from_utf8_lossy(&bytes)) {
            Ok(m) => m,
            Err(e) => {
                // A broken edit must never take the deployment down:
                // keep serving the last good generation and say so.
                println!(
                    "  [poll {polls}] {path} invalid, keeping generation {}: {e:#}",
                    orch.applied_generation()
                );
                continue;
            }
        };
        let next_hash = content_hash(&next);
        if next_hash == hash {
            println!("  [poll {polls}] formatting-only edit (hash unchanged)");
            continue;
        }
        let plan = diff(&applied, &next);
        println!(
            "  [poll {polls}] generation {} -> {} ({} action(s)):",
            applied.version,
            next.version,
            plan.actions.len()
        );
        let rep = reconcile(orch, &plan)?;
        for line in &rep.applied {
            println!("    applied  {line}");
        }
        for line in &rep.deferred {
            println!("    deferred {line}");
        }
        for line in &rep.rejected {
            println!("    rejected {line}");
        }
        applied = next;
        hash = next_hash;
    }
}

fn cmd_bench(flags: &Flags) -> Result<()> {
    let d = BenchConfig::default();
    let cfg = BenchConfig {
        batches: csv_nums(flags.get("--batches"), &d.batches)?,
        rates: csv_nums(flags.get("--rates"), &d.rates)?,
        requests: flags.usize_or("--requests", d.requests)?,
        models: match flags.get("--models") {
            Some(m) => csv_list(Some(m), &[]),
            None => d.models.clone(),
        },
        replicas: flags.usize_or("--replicas", d.replicas)?,
        queue_capacity: flags.usize_or("--queue", d.queue_capacity)?,
        workers: flags.usize_or("--workers", d.workers)?,
        time_scale: flags.f64_or("--time-scale", d.time_scale)?,
        payload_pool: flags.usize_or("--pool", d.payload_pool)?,
        slo_p99_ms: flags.f64_or("--slo", d.slo_p99_ms)?,
        seed: flags.usize_or("--seed", d.seed as usize)? as u64,
    };

    if flags.has("--hotpath") {
        if flags.has("--fused-only") {
            bail!("--hotpath and --fused-only are mutually exclusive");
        }
        // The hotpath harness saturates instead of pacing, so it wants
        // far more requests than a sweep point; default accordingly
        // unless the caller pinned --requests.
        let requests = match flags.get("--requests") {
            Some(_) => cfg.requests,
            None => 20_000,
        };
        let hcfg = BenchConfig { requests, ..cfg.clone() };
        println!(
            "hotpath: driving the null-executor fabric at saturation \
             ({requests} requests/arm, seed {})…\n",
            hcfg.seed,
        );
        let hp = bench::run_hotpath_bench(&hcfg)?;
        println!(
            "{:<22} {:>9} {:>12} {:>10} {:>10} {:>7} {:>8}",
            "arm", "payload", "rps/core", "p50 µs", "p99 µs", "dedup", "sha"
        );
        for a in &hp.arms {
            println!(
                "{:<22} {:>9} {:>12.0} {:>10.1} {:>10.1} {:>7} {:>8}",
                a.name, a.payload_len, a.rps_per_core, a.p50_us, a.p99_us,
                a.dedup_hits, a.sha_confirms,
            );
        }
        println!(
            "\nspeedup vs {} baseline: {:.2}x (≥ 2x: {}) | \
             rps/core ≥ {:.0} floor: {} | \
             two-tier dedup no regression: {} | conservation: {}",
            hp.baseline,
            hp.speedup_vs_baseline,
            yn(hp.speedup_ge_2x),
            hp.floor_rps_per_core,
            yn(hp.rps_per_core_above_floor),
            yn(hp.dedup_two_tier_no_regression),
            yn(hp.conservation),
        );
        let out = flags.get("--out").unwrap_or("BENCH_fabric.json");
        bench::write_json(
            out, &hcfg, &[], None, None, None, None, None, None, Some(&hp), None,
        )?;
        println!("wrote {out}");
        return Ok(());
    }

    println!(
        "sweeping {} batch sizes × {} rates × 2 execution modes \
         ({} requests/point, models {:?}, time-scale {})…\n",
        cfg.batches.len(),
        cfg.rates.len(),
        cfg.requests,
        cfg.models,
        cfg.time_scale,
    );
    let points = bench::run_sweep(&cfg)?;
    let (h, rows) = report::bench_table(&points);
    print!("{}", report::render_table(&h, &rows));

    // The control-plane comparisons (adaptive vs fixed batch sizing,
    // fixed replicas vs autoscaler), the tenancy measurement, the
    // continuum scenarios and the virtual-time determinism check ride
    // along unless --fused-only.
    let (control, autoscale, tenancy, continuum_bench, des_bench, resilience_bench, migration_bench) =
        if flags.has("--fused-only") {
            (None, None, None, None, None, None, None)
        } else {
        println!(
            "\nadaptive vs fixed max_batch across {} rates (SLO {:.0} ms)…\n",
            cfg.rates.len(),
            cfg.slo_p99_ms,
        );
        let sweep = bench::run_control_sweep(&cfg, &points)?;
        let (h, rows) = report::control_table(&sweep);
        print!("{}", report::render_table(&h, &rows));
        let v = bench::control_verdict(&sweep);
        println!(
            "\nadaptive matches best fixed throughput at peak: {} | \
             p99 ≤ best fixed at peak: {} | p99 within SLO at low rate: {}",
            yn(v.throughput_match_at_peak),
            yn(v.p99_le_best_fixed_at_peak),
            yn(v.p99_within_slo_at_low_rate),
        );

        println!("\nfixed single replica vs autoscaler at the peak rate…\n");
        let cmp = bench::run_autoscale_compare(&cfg)?;
        let (h, rows) = report::autoscale_table(&cmp);
        print!("{}", report::render_table(&h, &rows));
        println!(
            "\nautoscaler helps (no worse sheds, strictly fewer when fixed shed): {} | \
             eliminates sheds outright: {}",
            yn(cmp.helps()),
            yn(cmp.eliminates_sheds()),
        );

        println!("\ntenancy: hot tenant at 10x offered load vs an equal-weight cold tenant…\n");
        let ten = bench::run_tenancy_bench(&cfg)?;
        let (h, rows) = report::fabric_tenants(&ten.tenants);
        print!("{}", report::render_table(&h, &rows));
        println!(
            "\nweighted-fair drain within 10% of weights (deterministic, max err {:.1}%): {} | \
             quota exact at the burst bound: {} | shed strictly by ascending priority: {}",
            ten.verdicts.max_share_error * 100.0,
            yn(ten.verdicts.fair_share_within_tolerance),
            yn(ten.verdicts.quota_exact),
            yn(ten.verdicts.shed_priority_ordered),
        );

        println!(
            "\ncontinuum: spillover, replan and energy-policy scenarios over the \
             3-site testbed…\n"
        );
        let cont = bench::run_continuum_bench(&cfg)?;
        let (h, rows) = report::continuum_sites(&cont.drive.per_site);
        print!("{}", report::render_table(&h, &rows));
        println!(
            "\nspillover recovers on the next-ranked site: {} | mid-stream site loss \
             drops nothing: {} | energy-policy tradeoff visible: {} \
             (min-energy {:.4} J/req vs min-latency {:.4}; latency {:.2} → {:.2} ms)",
            yn(cont.verdicts.spillover_recovers),
            yn(cont.verdicts.replan_no_drop),
            yn(cont.verdicts.energy_policy_tradeoff),
            cont.verdicts.min_energy_energy_j,
            cont.verdicts.min_latency_energy_j,
            cont.verdicts.min_latency_ms,
            cont.verdicts.min_energy_ms,
        );

        println!(
            "\nvirtual time: replaying the million-user day twice on the \
             discrete-event core (seed {})…",
            cfg.seed,
        );
        let des = bench::run_des_bench(&cfg)?;
        println!(
            "{} submitted over {:.0} virtual seconds in {:.2}s wall \
             ({} events, {:.0} events/s)\n\
             bit-reproducible (same seed, byte-identical reports): {} | \
             seeds steer outcomes: {} | conservation: {}",
            des.submitted,
            des.virtual_s,
            des.wall_s,
            des.events,
            des.events_per_sec,
            yn(des.bit_reproducible),
            yn(des.seeds_differ),
            yn(des.conservation),
        );

        println!(
            "\nchaos: replaying site-loss-storm twice under the resilience defaults, \
             then hedge-disabled (seed {})…",
            cfg.seed,
        );
        let res = bench::run_resilience_bench(&cfg)?;
        println!(
            "{} submitted | {} failed | {} retries | hedges {} launched / {} won | \
             breaker trips {} | {} fault(s) injected\n\
             zero lost admitted work under the storm: {} | \
             hedging cuts tail p99 ({:.2} → {:.2} ms): {} | \
             breakers recover: {} | storm bit-reproducible: {}",
            res.submitted,
            res.failed,
            res.retries,
            res.hedges_launched,
            res.hedges_won,
            res.breaker_trips,
            res.faults_injected,
            yn(res.no_lost_requests_under_storm),
            res.p99_unhedged_ms,
            res.p99_hedged_ms,
            yn(res.hedging_cuts_tail_p99),
            yn(res.breaker_recovers),
            yn(res.storm_bit_reproducible),
        );

        println!(
            "\nmigration: live handover drill, forecast + energy-budget triggers, and \
             the mobile-day replay (seed {})…",
            cfg.seed,
        );
        let mig = bench::run_migration_bench(&cfg)?;
        println!(
            "{} submitted over mobile-day | {} handover(s) | {} fault(s) injected | \
             {} cache entr{} carried | {} feedback key(s) seeded | {} replica(s) retired\n\
             migration drops nothing: {} | warm cache carries: {} | \
             forecast triggers: {} | energy-budget triggers: {} | \
             mid-session handover drops nothing: {} | mobile-day bit-reproducible: {}",
            mig.submitted,
            mig.handovers,
            mig.faults_injected,
            mig.verdicts.cache_entries_moved,
            if mig.verdicts.cache_entries_moved == 1 { "y" } else { "ies" },
            mig.verdicts.feedback_keys_seeded,
            mig.verdicts.replicas_retired,
            yn(mig.verdicts.migration_no_drop),
            yn(mig.verdicts.warm_cache_carries),
            yn(mig.verdicts.forecast_triggers),
            yn(mig.verdicts.energy_budget_triggers),
            yn(mig.handover_no_drop),
            yn(mig.migration_bit_reproducible),
        );
        (Some(sweep), Some(cmp), Some(ten), Some(cont), Some(des), Some(res), Some(mig))
    };

    let out = flags.get("--out").unwrap_or("BENCH_fabric.json");
    bench::write_json(
        out,
        &cfg,
        &points,
        control.as_ref(),
        autoscale.as_ref(),
        tenancy.as_ref(),
        continuum_bench.as_ref(),
        des_bench.as_ref(),
        resilience_bench.as_ref(),
        None,
        migration_bench.as_ref(),
    )?;
    let beats = bench::fused_beats_per_item_at_batch_ge4(&points);
    match bench::best_speedup_at_batch_ge4(&points) {
        Some(best) => println!(
            "\nfused beats per-item at batch ≥ 4: {} (best {:.2}x) — wrote {out}",
            if beats { "YES" } else { "NO" },
            best
        ),
        None => println!("\n(no batch ≥ 4 in the sweep) — wrote {out}"),
    }
    Ok(())
}

fn yn(v: bool) -> &'static str {
    if v {
        "YES"
    } else {
        "NO"
    }
}

fn cmd_report(flags: &Flags) -> Result<()> {
    let what = flags.args.first().map(String::as_str).unwrap_or("all");
    let opts = Fig4Options {
        requests: flags.usize_or("--requests", 1000)?,
        real_requests: flags.usize_or("--real", 4)?,
        ..Default::default()
    };
    let artifacts = artifact::scan(ARTIFACTS_DIR).unwrap_or_default();

    if matches!(what, "table1" | "all") {
        println!("\nTABLE I — Inference Acceleration Frameworks by Platform and Precision");
        let (h, r) = report::table1();
        print!("{}", report::render_table(&h, &r));
        report::write_csv("reports/table1.csv", &h, &r)?;
    }
    if matches!(what, "table2" | "all") {
        println!("\nTABLE II — Experimental setup (simulated cluster)");
        let (h, r) = report::table2(&paper_testbed());
        print!("{}", report::render_table(&h, &r));
        report::write_csv("reports/table2.csv", &h, &r)?;
    }
    if matches!(what, "table3" | "all") {
        println!("\nTABLE III — Model characteristics (paper vs ours, DESIGN.md §7)");
        let (h, r) = report::table3(&artifacts);
        print!("{}", report::render_table(&h, &r));
        report::write_csv("reports/table3.csv", &h, &r)?;
    }
    if matches!(what, "fig3" | "all") {
        println!("\nFIG 3 — AI service variant generation time (cached conversions show python-measured times)");
        let rows = coordinator::generate(".", &GenerateOptions::default())?;
        let (h, r) = report::fig3(&rows);
        print!("{}", report::render_table(&h, &r));
        report::write_csv("reports/fig3.csv", &h, &r)?;
    }
    if matches!(what, "fig4" | "all") {
        println!("\nFIG 4 — Request latency per AI-framework-platform variant (* = simulated platform, DESIGN.md §2)");
        let engine = Engine::cpu()?;
        let rows = coordinator::bench_fig4(&engine, ARTIFACTS_DIR, &opts)?;
        let (h, r) = report::fig4(&rows);
        print!("{}", report::render_table(&h, &r));
        report::write_csv("reports/fig4.csv", &h, &r)?;
    }
    if matches!(what, "fig5" | "all") {
        println!("\nFIG 5 — Accelerated vs native TensorFlow (* = simulated platform)");
        let engine = Engine::cpu()?;
        let rows = coordinator::bench_fig5(&engine, ARTIFACTS_DIR, &opts)?;
        let (h, r) = report::fig5(&rows);
        print!("{}", report::render_table(&h, &r));
        report::write_csv("reports/fig5.csv", &h, &r)?;
        println!("\nAverage speedup per platform (paper: AGX 5.5x, ARM 2.7x, CPU 3.6x, GPU 7.6x):");
        for (p, s) in report::fig5_summary(&rows) {
            println!("  {p}: {s:.2}x");
        }
    }
    Ok(())
}
