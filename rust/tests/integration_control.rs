//! Integration: the fabric control plane — adaptive batch sizing
//! converging with load, the backlog-driven autoscaler respecting its
//! bounds and hysteresis, graceful replica retirement, and the response
//! cache's TTL behavior inside the full router.
//!
//! Everything runs on simulated executors (synthetic catalog + platform
//! cost models) with the test [`Gate`] making backlog deterministic:
//! while the gate is closed, every pod blocks at the start of its next
//! dispatch, so queue depths are exact and autoscaler ticks (stepped
//! manually via `Fabric::autoscale_tick` with `interval_ms: 0`) see
//! reproducible signals.

use std::sync::Arc;

use tf2aif::backend::{Backend, Policy};
use tf2aif::cluster::{paper_testbed, Cluster};
use tf2aif::fabric::sim::{synthetic_catalog, Gate};
use tf2aif::fabric::{
    AutoscaleConfig, Fabric, FabricConfig, Outcome, ScaleDirection, Submission, TenantSpec,
};
use tf2aif::workload::Arrival;

fn testbed() -> Cluster {
    let mut c = Cluster::new(paper_testbed());
    c.apply_kube_api_extension();
    c
}

/// Place a fabric over a single model so replica counts are exact.
fn place_one_model(model: &str, cfg: &FabricConfig, gate: Option<Arc<Gate>>) -> Fabric {
    let catalog: Vec<_> = synthetic_catalog()
        .into_iter()
        .filter(|a| a.manifest.model == model)
        .collect();
    let backend = Backend::new(catalog, Policy::MinLatency);
    Fabric::place_sim(&backend, testbed(), cfg, gate).unwrap()
}

/// Distinct payloads so neither dedup nor anything content-addressed can
/// collapse the flood.
fn distinct_payload(i: usize) -> Vec<f32> {
    vec![i as f32; 16]
}

#[test]
fn adaptive_batcher_converges_up_under_backlog_and_down_when_idle() {
    let cfg = FabricConfig {
        adaptive: true,
        max_batch: 16,
        min_batch: 1,
        slo_p99_ms: 1000.0, // generous: this test is about backlog adaptation
        queue_capacity: 64,
        replicas_per_model: 1,
        workers: 1,
        time_scale: 0.0,
        dedup: false,
        ..Default::default()
    };
    let gate = Gate::closed_gate();
    let fabric = place_one_model("lenet", &cfg, Some(Arc::clone(&gate)));
    let initial = fabric.batch_targets();
    assert_eq!(initial.len(), 1, "one pod, one controller");
    assert_eq!(initial[0].1, 4, "controller starts a quarter of the way up");

    // Build a deep deterministic backlog, then let it drain: the
    // controller must slow-start toward its bound.
    let mut pending = Vec::new();
    for i in 0..60 {
        match fabric.submit("lenet", distinct_payload(i)).unwrap() {
            Submission::Enqueued(rx) => pending.push(rx),
            Submission::Shed => panic!("queue bound 64 must admit a 60-deep flood"),
        }
    }
    gate.open();
    for rx in pending {
        assert!(matches!(rx.recv().unwrap(), Outcome::Completed(_)));
    }
    let after_backlog = fabric.batch_targets()[0].1;
    assert!(
        after_backlog >= 8,
        "sustained backlog must grow the drain size (got {after_backlog})"
    );

    // Quiet traffic (one request at a time) must decay it back down.
    for i in 1000..1030 {
        match fabric.submit("lenet", distinct_payload(i)).unwrap() {
            Submission::Enqueued(rx) => {
                assert!(matches!(rx.recv().unwrap(), Outcome::Completed(_)));
            }
            Submission::Shed => panic!("idle fabric must admit"),
        }
    }
    let after_idle = fabric.batch_targets()[0].1;
    assert!(
        after_idle <= 4,
        "idle traffic must decay the drain size (got {after_idle})"
    );
    fabric.shutdown();
}

#[test]
fn adaptive_batching_amortizes_dispatches_under_real_overload() {
    // No gate: a real open-loop overload on one slow pod.  The adaptive
    // controller must reach deep batches, visible as fleet dispatches
    // strictly below completed requests.
    let cfg = FabricConfig {
        adaptive: true,
        max_batch: 16,
        min_batch: 1,
        slo_p99_ms: 1000.0,
        queue_capacity: 64,
        replicas_per_model: 1,
        workers: 1,
        time_scale: 2.0,
        dedup: false,
        ..Default::default()
    };
    let fabric = place_one_model("lenet", &cfg, None);
    let run = fabric.run(300, Arrival::Poisson { rps: 20_000.0 }, 21).unwrap();
    assert!(run.fully_accounted());
    assert!(run.completed > 0);
    let reports = fabric.pod_reports(run.wall_s);
    let dispatches: u64 = reports.iter().map(|r| r.dispatches).sum();
    let served: u64 = reports.iter().map(|r| r.requests).sum();
    assert!(
        dispatches > 0 && dispatches < served,
        "adaptive batching must amortize: {dispatches} dispatches for {served} served"
    );
    fabric.shutdown();
}

fn manual_autoscale(min: usize, max: usize, hold: u32, cooldown: u32) -> Option<AutoscaleConfig> {
    Some(AutoscaleConfig {
        min_replicas: min,
        max_replicas: max,
        scale_up_backlog: 2.0,
        scale_down_backlog: 0.25,
        hold_ticks: hold,
        cooldown_ticks: cooldown,
        interval_ms: 0, // stepped manually: deterministic
        predictive: false,
    })
}

#[test]
fn autoscaler_scales_up_to_max_and_back_down_to_min() {
    let cfg = FabricConfig {
        queue_capacity: 64,
        max_batch: 4,
        replicas_per_model: 1,
        time_scale: 0.0,
        dedup: false,
        autoscale: manual_autoscale(1, 3, 2, 1),
        ..Default::default()
    };
    let gate = Gate::closed_gate();
    let fabric = place_one_model("lenet", &cfg, Some(Arc::clone(&gate)));
    assert_eq!(fabric.active_replicas("lenet"), 1);

    // Deterministic backlog: 40 gated requests on the single replica.
    let mut pending = Vec::new();
    for i in 0..40 {
        match fabric.submit("lenet", distinct_payload(i)).unwrap() {
            Submission::Enqueued(rx) => pending.push(rx),
            Submission::Shed => panic!("40-deep flood must fit a 64-deep queue"),
        }
    }

    // Sustained overload: hold 2 → second tick scales, cooldown 1 eats a
    // tick, then two more ticks for the next scale-up.  Extra ticks past
    // the ceiling must do nothing.
    for _ in 0..12 {
        fabric.autoscale_tick();
    }
    assert_eq!(
        fabric.active_replicas("lenet"),
        3,
        "sustained backlog must reach max_replicas"
    );
    for _ in 0..6 {
        fabric.autoscale_tick();
    }
    assert_eq!(fabric.active_replicas("lenet"), 3, "ceiling respected: no overshoot");
    let events = fabric.scale_events();
    assert_eq!(events.len(), 2, "exactly two scale-ups, counted once each");
    assert!(events.iter().all(|e| e.direction == ScaleDirection::Up));
    let nodes: std::collections::BTreeSet<_> =
        fabric.plans().into_iter().map(|p| p.node).collect();
    assert_eq!(nodes.len(), 3, "replicas must land on distinct nodes");

    // Drain, then sustained idle must retire back down to the floor and
    // no further.
    gate.open();
    for rx in pending {
        assert!(matches!(rx.recv().unwrap(), Outcome::Completed(_)));
    }
    for _ in 0..16 {
        fabric.autoscale_tick();
    }
    assert_eq!(fabric.active_replicas("lenet"), 1, "idle fleet must shrink to min");
    for _ in 0..6 {
        fabric.autoscale_tick();
    }
    assert_eq!(fabric.active_replicas("lenet"), 1, "floor respected: never below min");
    let events = fabric.scale_events();
    assert_eq!(
        events.iter().filter(|e| e.direction == ScaleDirection::Down).count(),
        2,
        "two retires back to the floor"
    );
    // The replica timeline survives in the report: retired pods stay
    // visible with their lifetimes.
    let reports = fabric.pod_reports(1.0);
    assert_eq!(reports.len(), 3, "retired pods remain in the report");
    assert_eq!(reports.iter().filter(|r| r.retired_ms.is_some()).count(), 2);
    let fleet = fabric.fleet_report(1.0);
    assert_eq!((fleet.scale_ups, fleet.scale_downs), (2, 2));
    assert_eq!(fleet.active_pods, 1);
    fabric.shutdown();
}

/// A fabric hosting exactly one variant of one model, so modeled
/// latency (and therefore the Little's-law forecast) is pinned.
fn place_one_variant(
    model: &str,
    variant: &str,
    cfg: &FabricConfig,
    gate: Option<Arc<Gate>>,
) -> Fabric {
    let catalog: Vec<_> = synthetic_catalog()
        .into_iter()
        .filter(|a| a.manifest.model == model && a.manifest.variant == variant)
        .collect();
    let backend = Backend::new(catalog, Policy::MinLatency);
    Fabric::place_sim(&backend, testbed(), cfg, gate).unwrap()
}

#[test]
fn predictive_autoscaler_scales_on_forecast_where_the_reactive_path_cannot() {
    // The reactive backlog threshold is set absurdly high, so ONLY the
    // predictive saturation signal (forecast ≥ 1 replica's worth of
    // offered concurrency) can scale this fleet.  The pod is pinned to
    // the CPU variant — the one platform with a second feasible node
    // for the scale-up — whose modeled inceptionv4 latency (~4.2 ms)
    // dwarfs the µs-scale gaps of a no-sleep submission flood, so the
    // offered load reads as hundreds of replicas' worth of concurrency
    // while executions (time_scale 0) are instant and real backlog
    // never materializes for the reactive path to claim credit.
    let auto = AutoscaleConfig {
        min_replicas: 1,
        max_replicas: 2,
        scale_up_backlog: 1e12, // reactive scale-up structurally off
        scale_down_backlog: 0.0,
        hold_ticks: 1,
        cooldown_ticks: 0,
        interval_ms: 0,
        predictive: true,
    };
    let cfg = FabricConfig {
        queue_capacity: 1024, // flood never sheds (no pressure signal either)
        max_batch: 8,
        replicas_per_model: 1,
        time_scale: 0.0,
        dedup: false,
        autoscale: Some(auto.clone()),
        ..Default::default()
    };
    let fabric = place_one_variant("inceptionv4", "CPU", &cfg, None);
    assert_eq!(fabric.active_replicas("inceptionv4"), 1);
    let mut pending = Vec::new();
    for i in 0..300 {
        match fabric.submit("inceptionv4", distinct_payload(i)).unwrap() {
            Submission::Enqueued(rx) => pending.push(rx),
            Submission::Shed => panic!("a 1024-deep queue must absorb a 300 flood"),
        }
    }
    // Tick immediately after the flood: the arrival EWMA is hot and the
    // forecast (offered rate × ~4.2 ms / 1 replica) is far beyond
    // saturation, while mean backlog — whatever it transiently is —
    // sits far below the 1e12 reactive threshold.
    fabric.autoscale_tick();
    assert_eq!(
        fabric.active_replicas("inceptionv4"),
        2,
        "the forecast alone must scale up — the reactive path is disabled"
    );
    let events = fabric.scale_events();
    assert!(
        events.iter().any(|e| e.trigger.starts_with("forecast")),
        "the trigger names the forecast: {events:?}"
    );
    for rx in pending {
        assert!(matches!(rx.recv().unwrap(), Outcome::Completed(_)));
    }
    fabric.shutdown();

    // The reactive fallback under the identical flood: no forecast, a
    // backlog nowhere near 1e12, no sheds → nothing ever scales, and
    // the idle side respects min_replicas.
    let cfg = FabricConfig {
        autoscale: Some(AutoscaleConfig { predictive: false, ..auto }),
        ..cfg
    };
    let fabric = place_one_variant("inceptionv4", "CPU", &cfg, None);
    let mut pending = Vec::new();
    for i in 0..300 {
        match fabric.submit("inceptionv4", distinct_payload(i)).unwrap() {
            Submission::Enqueued(rx) => pending.push(rx),
            Submission::Shed => panic!("a 1024-deep queue must absorb a 300 flood"),
        }
    }
    fabric.autoscale_tick();
    for rx in pending {
        assert!(matches!(rx.recv().unwrap(), Outcome::Completed(_)));
    }
    for _ in 0..3 {
        fabric.autoscale_tick();
    }
    assert_eq!(
        fabric.active_replicas("inceptionv4"),
        1,
        "without the forecast the reactive path sees nothing to scale on"
    );
    assert!(fabric.scale_events().is_empty());
    fabric.shutdown();
}

#[test]
fn tenant_slo_pins_batches_down_for_the_dominant_tenant() {
    // Two fabrics under the identical gated backlog, adaptive batching,
    // generous 1000 ms global SLO.  The strict fabric's only traffic
    // comes from a tenant carrying a 1 ms SLO override — every drained
    // batch is dominated by it, so the controller must back off to the
    // floor where the lax fabric slow-starts to deep batches.
    let mk_cfg = |slo: Option<f64>| {
        let mut spec = TenantSpec::new("tenant");
        spec.slo_p99_ms = slo;
        FabricConfig {
            adaptive: true,
            max_batch: 16,
            min_batch: 1,
            slo_p99_ms: 1000.0,
            queue_capacity: 64,
            replicas_per_model: 1,
            workers: 1,
            time_scale: 0.0,
            dedup: false,
            tenants: vec![spec],
            ..Default::default()
        }
    };
    let drive = |fabric: &Fabric, gate: &Gate| {
        let mut pending = Vec::new();
        for i in 0..60 {
            match fabric.submit_as("tenant", "lenet", distinct_payload(i)).unwrap() {
                Submission::Enqueued(rx) => pending.push(rx),
                Submission::Shed => panic!("queue bound 64 must admit 60"),
            }
        }
        gate.open();
        for rx in pending {
            assert!(matches!(rx.recv().unwrap(), Outcome::Completed(_)));
        }
    };

    let gate = Gate::closed_gate();
    let lax = place_one_model("lenet", &mk_cfg(None), Some(Arc::clone(&gate)));
    drive(&lax, &gate);
    let lax_target = lax.batch_targets()[0].1;
    assert!(lax_target >= 8, "no override: backlog grows the batch (got {lax_target})");
    lax.shutdown();

    let gate = Gate::closed_gate();
    let strict = place_one_model("lenet", &mk_cfg(Some(1.0)), Some(Arc::clone(&gate)));
    drive(&strict, &gate);
    let strict_target = strict.batch_targets()[0].1;
    assert_eq!(
        strict_target, 1,
        "the dominant tenant's 1 ms SLO must pin the drain size at the floor"
    );
    strict.shutdown();
}

#[test]
fn shed_burst_counts_as_overload_signal() {
    // Even with backlog thresholds set absurdly high, shedding since the
    // last tick must classify the model as overloaded and scale it up.
    let cfg = FabricConfig {
        queue_capacity: 2,
        max_batch: 1,
        replicas_per_model: 1,
        time_scale: 0.0,
        dedup: false,
        autoscale: Some(AutoscaleConfig {
            min_replicas: 1,
            max_replicas: 2,
            scale_up_backlog: 1e12,
            scale_down_backlog: 0.0,
            hold_ticks: 1,
            cooldown_ticks: 0,
            interval_ms: 0,
            predictive: false,
        }),
        ..Default::default()
    };
    let gate = Gate::closed_gate();
    let fabric = place_one_model("lenet", &cfg, Some(Arc::clone(&gate)));
    let mut pending = Vec::new();
    let mut shed = 0;
    for i in 0..16 {
        match fabric.submit("lenet", distinct_payload(i)).unwrap() {
            Submission::Enqueued(rx) => pending.push(rx),
            Submission::Shed => shed += 1,
        }
    }
    assert!(shed > 0, "a 16-deep burst into a 2-deep queue must shed");
    fabric.autoscale_tick();
    assert_eq!(fabric.active_replicas("lenet"), 2, "shed delta alone must trigger scale-up");
    gate.open();
    for rx in pending {
        assert!(matches!(rx.recv().unwrap(), Outcome::Completed(_)));
    }
    fabric.shutdown();
}

#[test]
fn retiring_a_replica_never_drops_admitted_requests() {
    // Two active replicas with queued (gated) work; force a scale-down
    // while the victim's queue is non-empty.  Every admitted request
    // must still complete — retirement is graceful.
    let cfg = FabricConfig {
        queue_capacity: 64,
        max_batch: 4,
        replicas_per_model: 2,
        time_scale: 0.0,
        dedup: false,
        autoscale: Some(AutoscaleConfig {
            min_replicas: 1,
            max_replicas: 2,
            // Thresholds rigged so ANY backlog level reads as idle:
            // the tick immediately retires one replica.
            scale_up_backlog: 1e12,
            scale_down_backlog: 1e12,
            hold_ticks: 1,
            cooldown_ticks: 0,
            interval_ms: 0,
            predictive: false,
        }),
        ..Default::default()
    };
    let gate = Gate::closed_gate();
    let fabric = place_one_model("lenet", &cfg, Some(Arc::clone(&gate)));
    assert_eq!(fabric.active_replicas("lenet"), 2);
    let mut pending = Vec::new();
    for i in 0..24 {
        match fabric.submit("lenet", distinct_payload(i)).unwrap() {
            Submission::Enqueued(rx) => pending.push(rx),
            Submission::Shed => panic!("two 64-deep queues must admit 24 requests"),
        }
    }
    fabric.autoscale_tick();
    assert_eq!(fabric.active_replicas("lenet"), 1, "one replica retired under load");

    gate.open();
    let mut completed = 0;
    for rx in pending {
        match rx.recv().expect("retired pods must still answer admitted requests") {
            Outcome::Completed(_) => completed += 1,
            Outcome::Failed(e) => panic!("unexpected failure: {e}"),
            Outcome::Shed => panic!("uniform priority never preempts admitted work"),
        }
    }
    assert_eq!(completed, 24, "graceful retire: nothing admitted is dropped");
    // New traffic still flows through the survivor.
    match fabric.submit("lenet", distinct_payload(9999)).unwrap() {
        Submission::Enqueued(rx) => {
            assert!(matches!(rx.recv().unwrap(), Outcome::Completed(_)));
        }
        Submission::Shed => panic!("survivor must admit"),
    }
    fabric.shutdown();
}

#[test]
fn artifact_redeploy_invalidates_cached_responses() {
    // Long TTL: only the redeploy hook can make the memo stale.
    let cfg = FabricConfig {
        time_scale: 0.0,
        cache_capacity: 8,
        cache_ttl_ms: 60_000,
        ..Default::default()
    };
    let fabric = place_one_model("lenet", &cfg, None);
    let payload = vec![0.5; 32];
    let serve = |fabric: &Fabric| match fabric.submit("lenet", payload.clone()).unwrap() {
        Submission::Enqueued(rx) => {
            assert!(matches!(rx.recv().unwrap(), Outcome::Completed(_)));
        }
        Submission::Shed => panic!("must admit"),
    };
    serve(&fabric);
    serve(&fabric);
    let served: u64 = fabric.pod_reports(1.0).iter().map(|r| r.requests).sum();
    assert_eq!(served, 1, "second round is a cache hit");
    assert_eq!(fabric.cache_stats().unwrap().hits, 1);

    // Redeploy: the cached response was computed by the old weights and
    // must never be served again, TTL notwithstanding.
    fabric.on_artifact_redeploy("lenet");
    serve(&fabric);
    let served: u64 = fabric.pod_reports(1.0).iter().map(|r| r.requests).sum();
    assert_eq!(served, 2, "post-redeploy submission re-executes");
    let stats = fabric.cache_stats().unwrap();
    assert_eq!(stats.hits, 1, "no pre-redeploy payload was returned");
    assert!(stats.invalidated >= 1, "invalidation is counted, got {stats:?}");

    // The fresh post-redeploy response caches normally again.
    serve(&fabric);
    assert_eq!(fabric.cache_stats().unwrap().hits, 2);
    let served: u64 = fabric.pod_reports(1.0).iter().map(|r| r.requests).sum();
    assert_eq!(served, 2);
    fabric.shutdown();
}

#[test]
fn redeploy_mid_stream_never_serves_a_pre_redeploy_payload() {
    // The race the generation stamp exists for: a leader is IN FLIGHT
    // when the redeploy lands.  Its memo must be dropped on insert, its
    // dedup entry purged so identical submissions execute fresh, and no
    // later lookup may see a pre-redeploy response.
    let cfg = FabricConfig {
        time_scale: 0.0,
        cache_capacity: 8,
        cache_ttl_ms: 60_000,
        replicas_per_model: 1,
        ..Default::default()
    };
    let gate = Gate::closed_gate();
    let fabric = place_one_model("lenet", &cfg, Some(Arc::clone(&gate)));
    let payload = vec![0.25; 32];
    let leader = match fabric.submit("lenet", payload.clone()).unwrap() {
        Submission::Enqueued(rx) => rx,
        Submission::Shed => panic!("must admit"),
    };
    // Redeploy while the leader is gated in flight.
    fabric.on_artifact_redeploy("lenet");
    // An identical submission must NOT piggyback on the pre-redeploy
    // execution (dedup entry purged) — it becomes a fresh leader.
    let follower = match fabric.submit("lenet", payload.clone()).unwrap() {
        Submission::Enqueued(rx) => rx,
        Submission::Shed => panic!("must admit"),
    };
    assert_eq!(fabric.dedup_hits(), 0, "post-redeploy submissions never attach");
    gate.open();
    assert!(matches!(leader.recv().unwrap(), Outcome::Completed(_)));
    assert!(matches!(follower.recv().unwrap(), Outcome::Completed(_)));
    let served: u64 = fabric.pod_reports(1.0).iter().map(|r| r.requests).sum();
    assert_eq!(served, 2, "both executions ran — nothing was memoized across the redeploy");
    // And the stale leader's memo was dropped at insert: a new identical
    // submission may only hit a response computed AFTER the redeploy.
    match fabric.submit("lenet", payload).unwrap() {
        Submission::Enqueued(rx) => {
            assert!(matches!(rx.recv().unwrap(), Outcome::Completed(_)));
        }
        Submission::Shed => panic!("must admit"),
    }
    let stats = fabric.cache_stats().unwrap();
    assert_eq!(
        stats.hits, 1,
        "the only cache hit comes from the post-redeploy follower's memo: {stats:?}"
    );
    fabric.shutdown();
}

#[test]
fn cache_ttl_expiry_forces_reexecution() {
    let cfg = FabricConfig {
        time_scale: 0.0,
        cache_capacity: 8,
        cache_ttl_ms: 1,
        ..Default::default()
    };
    let fabric = place_one_model("lenet", &cfg, None);
    let payload = vec![0.5; 32];
    for _ in 0..2 {
        match fabric.submit("lenet", payload.clone()).unwrap() {
            Submission::Enqueued(rx) => {
                assert!(matches!(rx.recv().unwrap(), Outcome::Completed(_)));
            }
            Submission::Shed => panic!("must admit"),
        }
        // Far past the 1 ms TTL: the memo must be stale on resubmit.
        std::thread::sleep(std::time::Duration::from_millis(25));
    }
    let served: u64 = fabric.pod_reports(1.0).iter().map(|r| r.requests).sum();
    assert_eq!(served, 2, "expired cache entries must not be served");
    let stats = fabric.cache_stats().unwrap();
    assert_eq!(stats.hits, 0);
    assert!(stats.expired >= 1, "expiry must be counted, got {stats:?}");
    fabric.shutdown();
}
