//! Property-style tests for the continuum planner — randomized
//! topologies under fixed seeds (deterministic, reproducible), checking
//! the invariants multi-site placement rests on:
//!
//! - No plan ever over-commits a node's memory: the sum of pod
//!   footprints the primaries bind on any node stays within its
//!   capacity (recomputed independently of the planner's own binds).
//! - An accelerator variant is never placed on a node that does not
//!   expose that platform (and, for device-plugin platforms, never
//!   beyond the node's accelerator slots).
//! - Planning — and *replanning* after a site loss or node drain — is
//!   bit-deterministic for a fixed seed.

use std::collections::BTreeMap;

use tf2aif::cluster::{platform_needs_accelerator, NodeSpec};
use tf2aif::continuum::{DeploymentPlan, LinkSpec, PlanPolicy, Planner, SiteSpec, SiteTier, Topology};
use tf2aif::fabric::sim::synthetic_catalog_for;
use tf2aif::util::rng::Rng;

const MODELS: [&str; 4] = ["lenet", "mobilenetv1", "resnet50", "inceptionv4"];
const PLATFORM_POOL: [&str; 5] = ["CPU", "GPU", "ALVEO", "AGX", "ARM"];

/// A random connected topology: 2–4 sites, 1–3 random nodes each, plus
/// one well-provisioned anchor node in site 0 so most instances are
/// globally feasible.
fn random_topology(rng: &mut Rng) -> Topology {
    let n_sites = 2 + rng.below(3);
    let tiers = [SiteTier::Cloud, SiteTier::Edge, SiteTier::FarEdge];
    let mut sites = Vec::new();
    for s in 0..n_sites {
        let mut nodes = Vec::new();
        for i in 0..1 + rng.below(3) {
            let mut platforms: Vec<String> = Vec::new();
            for _ in 0..1 + rng.below(3) {
                let p = PLATFORM_POOL[rng.below(PLATFORM_POOL.len())].to_string();
                if !platforms.contains(&p) {
                    platforms.push(p);
                }
            }
            nodes.push(NodeSpec {
                name: format!("s{s}-n{i}"),
                arch: "x86_64".into(),
                cpu_desc: String::new(),
                cpus: 8,
                memory_gb: 2.0 + rng.f64() * 8.0,
                accelerator: "sim".into(),
                platforms,
                slots: 1 + rng.below(2),
            });
        }
        if s == 0 {
            nodes.push(NodeSpec {
                name: "anchor".into(),
                arch: "x86_64".into(),
                cpu_desc: String::new(),
                cpus: 32,
                memory_gb: 64.0,
                accelerator: "sim".into(),
                platforms: PLATFORM_POOL.iter().map(|p| p.to_string()).collect(),
                slots: 2,
            });
        }
        sites.push(SiteSpec {
            name: format!("site{s}"),
            tier: tiers[rng.below(3)],
            nodes,
        });
    }
    let mut links = Vec::new();
    for s in 1..n_sites {
        links.push(LinkSpec {
            a: format!("site{}", s - 1),
            b: format!("site{s}"),
            rtt_ms: 1.0 + rng.f64() * 30.0,
            gbps: 0.5 + rng.f64() * 9.5,
        });
    }
    Topology::new(sites, links).expect("generated topologies are valid")
}

fn random_planner(seed: u64) -> Planner {
    let mut rng = Rng::new(seed);
    let topology = random_topology(&mut rng);
    // Non-empty random model subset.
    let mut models: Vec<&str> = MODELS.to_vec();
    rng.shuffle(&mut models);
    models.truncate(1 + rng.below(MODELS.len()));
    let catalog = synthetic_catalog_for(&models);
    let policies =
        [PlanPolicy::MinLatency, PlanPolicy::MinEnergy, PlanPolicy::Balanced];
    let demand = format!("site{}", rng.below(topology.sites().len()));
    let mut planner = Planner::new(
        topology,
        catalog,
        policies[rng.below(3)],
        demand,
    )
    .expect("demand site exists");
    planner.replicas_per_site = 1 + rng.below(3);
    planner
}

/// Recompute the memory and accelerator commitments of a plan's primary
/// binds per (site, node), independently of the planner's own
/// accounting, and assert them against the topology's capacities.
fn assert_no_overcommit(planner: &Planner, plan: &DeploymentPlan) {
    let mut mem: BTreeMap<(String, String), f64> = BTreeMap::new();
    let mut accel: BTreeMap<(String, String), usize> = BTreeMap::new();
    for placements in plan.assignments.values() {
        let primary = &placements[0];
        let artifact = planner
            .catalog
            .iter()
            .find(|a| {
                a.manifest.model == primary.model && a.manifest.variant == primary.variant
            })
            .expect("planned variant exists in the catalog");
        let pod_gb = artifact.manifest.weights_bytes as f64 / 1e9 + 0.25;
        assert_eq!(primary.replicas, primary.nodes.len());
        assert!(primary.replicas >= 1, "a primary always reserves capacity");
        assert!(primary.replicas <= planner.replicas_per_site);
        for node in &primary.nodes {
            let key = (primary.site.clone(), node.clone());
            *mem.entry(key.clone()).or_insert(0.0) += pod_gb;
            if platform_needs_accelerator(&primary.variant) {
                *accel.entry(key).or_insert(0) += 1;
            }
        }
        // Alternates reserve nothing.
        for alt in &placements[1..] {
            assert_eq!(alt.replicas, 0);
            assert!(alt.nodes.is_empty());
        }
    }
    for ((site, node), used) in &mem {
        let spec = node_spec(planner, site, node);
        assert!(
            *used <= spec.memory_gb + 1e-9,
            "{site}/{node}: {used:.3} GB committed over {} GB",
            spec.memory_gb
        );
    }
    for ((site, node), used) in &accel {
        let spec = node_spec(planner, site, node);
        assert!(
            *used <= spec.slots,
            "{site}/{node}: {used} accelerator pods over {} slots",
            spec.slots
        );
    }
}

/// Every placement (primary or alternate) only ever names a node that
/// exposes the variant's platform — an accelerator variant can never
/// land on a node without that accelerator.
fn assert_platform_feasible(planner: &Planner, plan: &DeploymentPlan) {
    for placements in plan.assignments.values() {
        for p in placements {
            let base = p.variant.trim_end_matches("_TF");
            for node in std::iter::once(&p.node).chain(p.nodes.iter()) {
                let spec = node_spec(planner, &p.site, node);
                assert!(
                    spec.platforms.iter().any(|pl| pl == base),
                    "{}: node {}/{} does not expose {}",
                    p.model,
                    p.site,
                    node,
                    p.variant
                );
                if platform_needs_accelerator(&p.variant) {
                    assert!(spec.slots >= 1, "{}/{}: accelerator variant, no slots", p.site, node);
                }
            }
        }
    }
}

fn node_spec<'a>(planner: &'a Planner, site: &str, node: &str) -> &'a NodeSpec {
    planner
        .topology
        .site(site)
        .expect("placement names a known site")
        .nodes
        .iter()
        .find(|n| n.name == node)
        .expect("placement names a known node")
}

#[test]
fn plans_never_overcommit_and_respect_accelerators() {
    let mut feasible = 0;
    for seed in 0..24u64 {
        let planner = random_planner(seed);
        // Random instances may legitimately be infeasible (a surviving
        // site out of slots); the invariants apply to every plan that
        // exists.
        let Ok(plan) = planner.plan() else { continue };
        feasible += 1;
        assert_no_overcommit(&planner, &plan);
        assert_platform_feasible(&planner, &plan);
    }
    assert!(feasible >= 12, "most random instances must be plannable, got {feasible}");
}

#[test]
fn replanning_is_deterministic_for_a_fixed_seed() {
    for seed in 0..12u64 {
        let base = || random_planner(seed);
        // The base plan reproduces bit-identically.
        let a = base().plan();
        let b = base().plan();
        assert_eq!(format!("{a:?}"), format!("{b:?}"), "seed {seed}");

        // Losing the first site: the replan reproduces too (and its
        // invariants hold when it succeeds).
        let lose = || {
            let mut p = base();
            p.lost_sites.insert("site0".to_string());
            p
        };
        let la = lose().plan();
        let lb = lose().plan();
        assert_eq!(format!("{la:?}"), format!("{lb:?}"), "seed {seed} after site loss");
        if let Ok(plan) = &la {
            let p = lose();
            assert_no_overcommit(&p, plan);
            assert_platform_feasible(&p, plan);
            for placements in plan.assignments.values() {
                assert!(placements.iter().all(|sp| sp.site != "site0"));
            }
        }

        // Draining one node reproduces as well, and the node vanishes
        // from the plan.
        let drain = || {
            let mut p = base();
            p.drained_nodes.insert(("site0".to_string(), "anchor".to_string()));
            p
        };
        let da = drain().plan();
        let db = drain().plan();
        assert_eq!(format!("{da:?}"), format!("{db:?}"), "seed {seed} after drain");
        if let Ok(plan) = &da {
            for placements in plan.assignments.values() {
                for sp in placements {
                    assert!(
                        !(sp.site == "site0"
                            && (sp.node == "anchor" || sp.nodes.iter().any(|n| n == "anchor"))),
                        "drained node must not appear: {sp:?}"
                    );
                }
            }
        }
    }
}
