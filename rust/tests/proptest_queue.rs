//! Property-style tests for the fabric queues — randomized inputs under
//! fixed seeds (deterministic, reproducible), checking the invariants
//! the fabric's correctness rests on:
//!
//! - FIFO order is preserved per producer under concurrent producers.
//! - A linger is cut short by `close`, never waited out.
//! - Shutdown vs empty is unambiguous: consumers block or exit, they
//!   never spin, and every admitted item is popped exactly once.
//! - The multi-lane queue conserves items (admitted = popped + evicted
//!   + remaining), holds its capacity and per-lane bounds, and only
//!   ever preempts strictly-lower-priority work.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use tf2aif::fabric::queue::{BoundedQueue, LaneConfig, Push, TenantQueue};
use tf2aif::util::rng::Rng;

#[test]
fn fifo_order_per_producer_survives_concurrent_producers() {
    // 4 producers × 300 items, one consumer popping random-size batches:
    // within each producer's stream, sequence numbers must come out
    // strictly increasing (the queue is FIFO per arrival order, and one
    // producer's pushes are ordered by its own program order).
    for seed in [3u64, 17, 99] {
        let q = Arc::new(BoundedQueue::<(usize, usize)>::new(4096));
        let producers: Vec<_> = (0..4)
            .map(|p| {
                let q = Arc::clone(&q);
                std::thread::spawn(move || {
                    for i in 0..300 {
                        q.try_push((p, i)).expect("capacity 4096 never bounces");
                    }
                })
            })
            .collect();
        let consumer = {
            let q = Arc::clone(&q);
            std::thread::spawn(move || {
                let mut rng = Rng::new(seed);
                let mut last_seen = [None::<usize>; 4];
                let mut total = 0usize;
                while let Some(batch) = q.pop_batch(1 + rng.below(16)) {
                    assert!(!batch.is_empty(), "Some(batch) is never empty");
                    for (p, i) in batch {
                        if let Some(prev) = last_seen[p] {
                            assert!(
                                i > prev,
                                "seed {seed}: producer {p} reordered ({prev} then {i})"
                            );
                        }
                        last_seen[p] = Some(i);
                        total += 1;
                    }
                }
                total
            })
        };
        for p in producers {
            p.join().unwrap();
        }
        q.close();
        assert_eq!(consumer.join().unwrap(), 1200, "seed {seed}: every item popped once");
    }
}

#[test]
fn linger_is_cut_short_by_close_under_randomized_timing() {
    // Whatever the (seeded) arrival pattern inside the window, closing
    // the queue must end a 30-second linger immediately and deliver
    // everything that was queued.
    for seed in 0..8u64 {
        let mut rng = Rng::new(0xC105E ^ seed);
        let q = Arc::new(BoundedQueue::<u64>::new(64));
        q.try_push(seed).unwrap();
        let q2 = Arc::clone(&q);
        let consumer = std::thread::spawn(move || {
            q2.pop_batch_linger(64, Duration::from_secs(30))
        });
        let extra = rng.below(5);
        for i in 0..extra {
            std::thread::sleep(Duration::from_millis(rng.below(10) as u64));
            q.try_push(1000 + i as u64).unwrap();
        }
        std::thread::sleep(Duration::from_millis(rng.below(15) as u64));
        let t0 = Instant::now();
        q.close();
        let batch = consumer.join().unwrap().expect("queued items must be delivered");
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "seed {seed}: close must cut the linger short"
        );
        assert_eq!(batch.len(), 1 + extra, "seed {seed}: nothing lost in the window");
        assert_eq!(q.pop_batch(8), None, "then the shutdown signal");
    }
}

#[test]
fn shutdown_vs_empty_never_spins_and_conserves_items() {
    // 4 consumers over randomized bursty production.  `Some(batch)` is
    // never empty, so a consumer's loop iterations are bounded by items
    // popped — if the empty-vs-shutdown disambiguation were broken, a
    // spinning consumer would blow through the iteration bound (or hang
    // forever on a missed close, failing the join).
    for seed in [5u64, 23, 2024] {
        let q = Arc::new(BoundedQueue::<u64>::new(8192));
        let wakeups = Arc::new(AtomicUsize::new(0));
        let consumers: Vec<_> = (0..4)
            .map(|_| {
                let q = Arc::clone(&q);
                let wakeups = Arc::clone(&wakeups);
                std::thread::spawn(move || {
                    let mut got = 0usize;
                    while let Some(batch) = q.pop_batch(8) {
                        wakeups.fetch_add(1, Ordering::Relaxed);
                        assert!(!batch.is_empty());
                        got += batch.len();
                    }
                    got
                })
            })
            .collect();
        let mut rng = Rng::new(seed);
        let mut pushed = 0usize;
        for _ in 0..50 {
            let burst = rng.below(40);
            for i in 0..burst {
                q.try_push(i as u64).unwrap();
                pushed += 1;
            }
            if rng.below(3) == 0 {
                std::thread::sleep(Duration::from_millis(1));
            }
        }
        q.close();
        let got: usize = consumers.into_iter().map(|c| c.join().unwrap()).sum();
        assert_eq!(got, pushed, "seed {seed}: admitted items are popped exactly once");
        assert!(
            wakeups.load(Ordering::Relaxed) <= pushed + 4,
            "seed {seed}: a consumer woke without work — the shutdown-vs-empty \
             disambiguation is spinning"
        );
    }
}

#[test]
fn tenant_queue_randomized_invariants_hold() {
    // Random (lane, priority) pushes against random pops: conservation,
    // the capacity bound, per-lane caps, and the preemption contract
    // (evicted work is always strictly lower priority than what
    // displaced it) must hold at every step.
    for seed in [11u64, 47, 0xFEED] {
        let mut rng = Rng::new(seed);
        let capacity = 1 + rng.below(24);
        let n_lanes = 1 + rng.below(4);
        let lanes: Vec<LaneConfig> = (0..n_lanes)
            .map(|_| LaneConfig {
                weight: 1 + rng.below(5) as u32,
                max_slots: 1 + rng.below(capacity),
            })
            .collect();
        let caps: Vec<usize> = lanes.iter().map(|l| l.max_slots).collect();
        let q: TenantQueue<(usize, u8)> = TenantQueue::new(capacity, lanes);
        let (mut admitted, mut popped, mut evicted) = (0usize, 0usize, 0usize);
        for _ in 0..600 {
            if rng.below(3) < 2 {
                let lane = rng.below(n_lanes);
                let prio = rng.below(3) as u8;
                match q.push(lane, prio, (lane, prio)) {
                    Push::Admitted(ev) => {
                        admitted += 1;
                        for (_, evicted_prio) in &ev {
                            assert!(
                                *evicted_prio < prio,
                                "seed {seed}: preempted prio {evicted_prio} by {prio}"
                            );
                        }
                        evicted += ev.len();
                    }
                    Push::Rejected((l, p)) => {
                        assert_eq!((l, p), (lane, prio), "rejected item comes back intact");
                    }
                }
            } else if !q.is_empty() {
                popped += q.pop_batch(1 + rng.below(6)).expect("non-empty pops Some").len();
            }
            assert!(q.len() <= capacity, "seed {seed}: capacity bound violated");
            for (lane, cap) in caps.iter().enumerate() {
                assert!(
                    q.lane_len(lane) <= *cap,
                    "seed {seed}: lane {lane} above its slot cap"
                );
            }
            assert_eq!(
                admitted,
                popped + evicted + q.len(),
                "seed {seed}: items must be conserved"
            );
        }
        // Drain everything; conservation must close out exactly.
        q.close();
        while let Some(batch) = q.pop_batch(16) {
            popped += batch.len();
        }
        assert_eq!(admitted, popped + evicted, "seed {seed}: final conservation");
    }
}
