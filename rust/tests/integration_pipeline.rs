//! Integration: the generation pipeline — Converter freshness, Composer
//! bundles, Registry round-trips, archives, and the backend+cluster
//! deployment flow over real artifacts.

use tf2aif::artifact::Artifact;
use tf2aif::backend::{Backend, Policy};
use tf2aif::cluster::{paper_testbed, Cluster};
use tf2aif::composer::{self, tar, ComposeOptions};
use tf2aif::converter::{Converter, Job};
use tf2aif::registry::Registry;

fn have_artifacts() -> bool {
    std::path::Path::new("artifacts/lenet_CPU/manifest.json").exists()
}

fn tmpdir(name: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("tf2aif-it-{name}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

#[test]
fn converter_is_idempotent_on_fresh_artifacts() {
    if !have_artifacts() {
        return;
    }
    let conv = Converter::new(".");
    let jobs: Vec<Job> = ["CPU", "GPU", "ALVEO"]
        .iter()
        .map(|v| Job { model: "lenet".into(), variant: v.to_string() })
        .collect();
    let t0 = std::time::Instant::now();
    let reports = conv.convert_all(jobs);
    assert!(t0.elapsed().as_secs_f64() < 5.0, "fresh artifacts must be near-instant");
    for r in reports {
        let r = r.unwrap();
        assert!(r.skipped, "{}_{} re-ran despite freshness", r.model, r.variant);
        assert!(r.convert_s >= 0.0 && r.lower_s >= 0.0);
    }
}

#[test]
fn composed_bundle_roundtrips_through_registry_and_archive() {
    if !have_artifacts() {
        return;
    }
    let art = Artifact::load("artifacts/mobilenetv1_ALVEO").unwrap();
    let opts = ComposeOptions { port: 9000, batch_size: 4, extra_env: vec![
        ("LOG_LEVEL".into(), "debug".into()),
    ]};
    let server = composer::compose_server(&art, &opts).unwrap();
    let client = composer::compose_client(&art, &opts).unwrap();

    // ALVEO carries the DPU program; layer set is complete.
    let names: Vec<&str> = server.layers.iter().map(|l| l.name.as_str()).collect();
    assert_eq!(
        names,
        vec!["env.json", "model.hlo.txt", "weights.bin", "manifest.json",
             "dpu_program.bin", "server.json"]
    );
    assert!(client.layers.iter().any(|l| l.name == "fixtures.bin"));

    // Registry round-trip is byte-exact.
    let reg = Registry::open(tmpdir("pipeline")).unwrap();
    reg.push(&server).unwrap();
    reg.push(&client).unwrap();
    let back = reg.pull("mobilenetv1_ALVEO").unwrap();
    assert_eq!(back.digest, server.digest);
    for (a, b) in back.layers.iter().zip(&server.layers) {
        assert_eq!(a.data, b.data, "layer {} corrupted", a.name);
    }

    // Archive (gzipped ustar) round-trips.
    let gz = server.to_archive().unwrap();
    let mut dec = flate2::read::GzDecoder::new(&gz[..]);
    let entries = tar::read(&mut dec).unwrap();
    assert_eq!(entries.len(), 1 + server.layers.len(), "index + layers");
    assert_eq!(entries[0].name, "index.json");
    let weights = entries.iter().find(|e| e.name == "layers/weights.bin").unwrap();
    assert_eq!(
        weights.data.len() as u64,
        art.manifest.weights_bytes,
        "weights layer intact"
    );
}

#[test]
fn bundle_digests_are_stable_and_config_sensitive() {
    if !have_artifacts() {
        return;
    }
    let art = Artifact::load("artifacts/lenet_GPU").unwrap();
    let o1 = ComposeOptions::default();
    let b1 = composer::compose_server(&art, &o1).unwrap();
    let b2 = composer::compose_server(&art, &o1).unwrap();
    assert_eq!(b1.digest, b2.digest, "composition must be reproducible");
    let o2 = ComposeOptions { batch_size: 16, ..ComposeOptions::default() };
    let b3 = composer::compose_server(&art, &o2).unwrap();
    assert_ne!(b1.digest, b3.digest, "user config must change identity");
}

#[test]
fn dpu_program_only_for_alveo_and_scales() {
    if !have_artifacts() {
        return;
    }
    let opts = ComposeOptions::default();
    let has_dpu = |id: &str| {
        let art = Artifact::load(format!("artifacts/{id}")).unwrap();
        let b = composer::compose_server(&art, &opts).unwrap();
        b.layers
            .iter()
            .find(|l| l.name == "dpu_program.bin")
            .map(|l| l.data.len())
    };
    assert_eq!(has_dpu("lenet_GPU"), None);
    assert_eq!(has_dpu("lenet_ARM"), None, "int8 but not a DPU target");
    let small = has_dpu("lenet_ALVEO").expect("ALVEO ships a DPU program");
    let large = has_dpu("resnet50_ALVEO").expect("ALVEO ships a DPU program");
    assert!(large > 5 * small, "DPU program must scale with model: {small} vs {large}");
}

#[test]
fn backend_deploys_all_four_models_on_paper_testbed() {
    if !have_artifacts() {
        return;
    }
    let mut cluster = Cluster::new(paper_testbed());
    cluster.apply_kube_api_extension();
    let backend = Backend::new(tf2aif::artifact::scan("artifacts").unwrap(), Policy::MinLatency);
    // Selection only (no PJRT compile) keeps this test fast.
    let mut used_nodes = std::collections::BTreeSet::new();
    for model in ["lenet", "mobilenetv1", "resnet50", "inceptionv4"] {
        let d = backend.select(model, &cluster).unwrap();
        cluster
            .bind(&d.aif, &d.variant, &d.node, 0.5)
            .unwrap_or_else(|e| panic!("{model}: {e}"));
        used_nodes.insert(d.node.clone());
        assert!(!d.variant.ends_with("_TF"));
    }
    assert!(used_nodes.len() >= 2, "load should spread across nodes");
}

#[test]
fn registry_tags_cover_server_and_client() {
    if !have_artifacts() {
        return;
    }
    let reg = Registry::open(tmpdir("tags")).unwrap();
    for id in ["lenet_CPU", "lenet_GPU"] {
        let art = Artifact::load(format!("artifacts/{id}")).unwrap();
        let o = ComposeOptions::default();
        reg.push(&composer::compose_server(&art, &o).unwrap()).unwrap();
        reg.push(&composer::compose_client(&art, &o).unwrap()).unwrap();
    }
    let tags = reg.tags().unwrap();
    assert_eq!(
        tags,
        vec!["lenet_CPU", "lenet_CPU-client", "lenet_GPU", "lenet_GPU-client"]
    );
    let stats = reg.stats().unwrap();
    assert_eq!(stats.tags_by_kind.get("server"), Some(&2));
    assert_eq!(stats.tags_by_kind.get("client"), Some(&2));
}
