//! Equivalence suite for the hot-path rework (sharded registry snapshot +
//! two-tier dedup hashing): the fabric must produce **identical verdict
//! accounting and conservation sums** regardless of the tier-1 pre-hash
//! width.  `FabricConfig::prehash_mask` narrows the vendored FNV-1a
//! pre-hash that indexes the dedup map — `!0` is production, `0x7` forces
//! frequent 64-bit collisions, `0` funnels every request into ONE bucket —
//! and because an occupied bucket is always confirmed by sha256 before a
//! request attaches, none of that may change what the caller observes.
//!
//! Covered here:
//! - gated deterministic floods: dedup_hits is exactly the duplicate
//!   count under every mask (identical payloads collapse),
//! - forced collisions: distinct payloads sharing a pre-hash bucket are
//!   NEVER collapsed (the sha256 confirm rejects them) and the confirm
//!   counter proves the second tier actually ran,
//! - threaded saturation drives: conservation sums and the
//!   `completed = pod-served + deduped` identity hold under every mask,
//! - the virtual-time path (`--virtual-time` / DES): payload-free by
//!   construction, so it must stay byte-reproducible and conserving —
//!   asserted against the same golden scenario the CI gate replays.

use std::sync::Arc;

use tf2aif::backend::{Backend, Policy};
use tf2aif::cluster::{paper_testbed, Cluster};
use tf2aif::continuum::des::canned;
use tf2aif::fabric::des::run_des;
use tf2aif::fabric::sim::{synthetic_catalog, Gate};
use tf2aif::fabric::{Fabric, FabricConfig, Outcome, Submission};
use tf2aif::workload::Arrival;

/// The masks under test: production width, a 3-bit hash (collisions
/// near-certain), and the degenerate single-bucket hash.
const MASKS: &[u64] = &[!0u64, 0x7, 0x0];

fn testbed() -> Cluster {
    let mut c = Cluster::new(paper_testbed());
    c.apply_kube_api_extension();
    c
}

fn place(cfg: &FabricConfig, gate: Option<Arc<Gate>>) -> Fabric {
    let backend = Backend::new(synthetic_catalog(), Policy::MinLatency);
    Fabric::place_sim(&backend, testbed(), cfg, gate).unwrap()
}

fn gated_cfg(prehash_mask: u64) -> FabricConfig {
    FabricConfig {
        queue_capacity: 64,
        max_batch: 4,
        workers: 1,
        time_scale: 0.0,
        dedup: true,
        cache_capacity: 0,
        prehash_mask,
        ..Default::default()
    }
}

/// Distinct-by-content payloads: only element 0 varies, so narrow masks
/// collide maximally while the exact bytes stay unique.
fn distinct_payloads(n: usize) -> Vec<Arc<[f32]>> {
    (0..n)
        .map(|i| {
            let mut p = vec![0.5f32; 32];
            p[0] = i as f32;
            p.into()
        })
        .collect()
}

/// Gate the executors closed, submit `rounds` passes over `pool`, open
/// the gate, and return the observed accounting tuple
/// `(enqueued, shed, completed, dedup_hits, sha_confirms)`.
fn gated_flood(
    mask: u64,
    pool: &[Arc<[f32]>],
    rounds: usize,
) -> (usize, usize, usize, u64, u64) {
    let cfg = gated_cfg(mask);
    let gate = Gate::closed_gate();
    let fabric = place(&cfg, Some(Arc::clone(&gate)));
    let model = "lenet";
    let mut pending = Vec::new();
    let mut shed = 0usize;
    for _ in 0..rounds {
        for payload in pool {
            match fabric.submit(model, Arc::clone(payload)).unwrap() {
                Submission::Enqueued(rx) => pending.push(rx),
                Submission::Shed => shed += 1,
            }
        }
    }
    let enqueued = pending.len();
    let (dedup_hits, sha_confirms) = (fabric.dedup_hits(), fabric.sha_confirms());
    gate.open();
    let mut completed = 0usize;
    for rx in pending {
        match rx.recv().expect("every admitted request gets a verdict") {
            Outcome::Completed(_) => completed += 1,
            other => panic!("gated sim pods never shed/fail admitted work: {other:?}"),
        }
    }
    fabric.shutdown();
    (enqueued, shed, completed, dedup_hits, sha_confirms)
}

#[test]
fn verdict_accounting_is_identical_under_every_prehash_mask() {
    // 8 distinct payloads × 3 rounds while the executors are gated: the
    // first round inserts 8 leaders, every later round attaches as a
    // follower.  That arithmetic — 24 admitted, 16 dedup hits — must be
    // bit-equal no matter how collided the tier-1 index is.
    let pool = distinct_payloads(8);
    let baseline = gated_flood(MASKS[0], &pool, 3);
    for &mask in MASKS {
        let got = gated_flood(mask, &pool, 3);
        assert_eq!(
            (got.0, got.1, got.2, got.3),
            (baseline.0, baseline.1, baseline.2, baseline.3),
            "mask {mask:#x}: accounting diverged from production-width hash"
        );
        assert_eq!(got.0, 24, "mask {mask:#x}: every submission admitted");
        assert_eq!(got.1, 0, "mask {mask:#x}: nothing shed below the bound");
        assert_eq!(got.2, 24, "mask {mask:#x}: every admitted request completed");
        assert_eq!(got.3, 16, "mask {mask:#x}: exactly the duplicates collapsed");
    }
}

#[test]
fn forced_collisions_never_collapse_distinct_payloads() {
    // Mask 0 funnels ALL requests into one dedup bucket.  Distinct
    // payloads must still execute independently — the sha256 confirm is
    // what keeps a 64-bit collision from corrupting verdicts — and the
    // confirm counter must prove the second tier actually ran.
    let pool = distinct_payloads(8);
    let (enqueued, shed, completed, dedup_hits, sha_confirms) =
        gated_flood(0, &pool, 1);
    assert_eq!((enqueued, shed), (8, 0));
    assert_eq!(dedup_hits, 0, "distinct payloads must never dedup");
    assert_eq!(completed, 8, "each collided-but-distinct request ran on its own");
    assert!(
        sha_confirms > 0,
        "an occupied bucket probe must have computed confirm digests"
    );
    // Production-width hash on the same distinct pool: buckets never
    // collide, so the sha256 tier is never consulted at all.
    let (.., full_hits, full_confirms) = gated_flood(!0, &pool, 1);
    assert_eq!(full_hits, 0);
    assert_eq!(
        full_confirms, 0,
        "full-width pre-hash on distinct traffic must not pay for sha256"
    );
}

#[test]
fn duplicate_collapse_survives_forced_collisions() {
    // The property from the issue: forced 64-bit pre-hash collisions
    // still dedup correctly via the sha256 confirm.  A pool of 4
    // payloads each submitted twice while gated must yield exactly 4
    // dedup hits under the production hash AND under the degenerate
    // single-bucket hash.
    let pool = distinct_payloads(4);
    let mut doubled = Vec::new();
    for p in &pool {
        doubled.push(Arc::clone(p));
        doubled.push(Arc::clone(p));
    }
    let mut per_mask = Vec::new();
    for &mask in MASKS {
        let got = gated_flood(mask, &doubled, 1);
        assert_eq!(got.3, 4, "mask {mask:#x}: one follower per distinct payload");
        assert_eq!(got.2, 8, "mask {mask:#x}: followers still receive verdicts");
        per_mask.push((got.0, got.1, got.2, got.3));
    }
    assert!(
        per_mask.windows(2).all(|w| w[0] == w[1]),
        "accounting must be mask-invariant: {per_mask:?}"
    );
}

#[test]
fn threaded_saturation_conserves_under_every_mask() {
    // A real threaded drive (Poisson arrivals, pooled payloads so
    // in-flight overlap actually exercises the dedup map): conservation
    // and the `completed = pod-served + deduped` identity must hold for
    // every mask.  Overlap timing is scheduler-dependent, so dedup_hits
    // itself may vary run to run — the sums may not.
    let pool = distinct_payloads(4);
    for &mask in MASKS {
        let cfg = FabricConfig {
            time_scale: 0.0,
            dedup: true,
            cache_capacity: 0,
            prehash_mask: mask,
            ..Default::default()
        };
        let fabric = place(&cfg, None);
        let run = fabric
            .run_with(300, Arrival::Poisson { rps: 50_000.0 }, 7, |_, _, i| {
                Arc::clone(&pool[i % pool.len()])
            })
            .unwrap();
        assert!(run.fully_accounted(), "mask {mask:#x}: conservation");
        assert_eq!(run.failed, 0, "mask {mask:#x}: sim pods never fail");
        assert_eq!(run.completed + run.shed, 300, "mask {mask:#x}");
        let fleet = fabric.fleet_report(run.wall_s);
        assert_eq!(
            fleet.requests + fleet.deduped,
            run.completed as u64,
            "mask {mask:#x}: every completion is a pod execution or a dedup attach"
        );
        fabric.shutdown();
    }
}

#[test]
fn virtual_time_path_is_unchanged_and_conserving() {
    // The DES engine never touches payload bytes, so the hot-path work
    // cannot move it — prove it: the golden scenario the CI determinism
    // gate replays is still byte-reproducible and conserving.
    let first = run_des(&canned("diurnal-day", 11).unwrap()).unwrap();
    let second = run_des(&canned("diurnal-day", 11).unwrap()).unwrap();
    assert!(first.conservation_holds(), "virtual-time conservation");
    assert!(first.submitted > 0);
    assert_eq!(
        first.canonical_json(),
        second.canonical_json(),
        "virtual-time replay must stay byte-identical after the hot-path rework"
    );
}
