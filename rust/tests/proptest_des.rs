//! Property-style tests for the discrete-event core — randomized inputs
//! under fixed seeds (deterministic, reproducible), checking the
//! invariants bit-reproducible replay rests on:
//!
//! - The event heap never yields events out of time order, and events
//!   scheduled for the same instant pop in schedule order (FIFO among
//!   ties — the property that makes the replay canonical rather than
//!   merely time-sorted).
//! - The virtual clock is monotone over any sorted drive and panics on
//!   regression instead of silently corrupting measurements.
//! - Randomly generated multi-site scenarios conserve every request
//!   (`submitted = completed + cache_hits + shed + quota_shed`) and
//!   replay byte-identically.

use tf2aif::fabric::des::{
    run_des, Clock, DesConfig, DesModel, DesScenario, DesSite, EventHeap, SimClock,
};
use tf2aif::fabric::FaultPlan;
use tf2aif::util::rng::Rng;
use tf2aif::workload::RateCurve;

#[test]
fn heap_never_yields_events_out_of_time_order() {
    for seed in [1u64, 7, 42, 1234] {
        let mut rng = Rng::new(seed);
        let mut heap = EventHeap::new();
        for i in 0..5000usize {
            heap.schedule(rng.below(1000) as u64 * 17, i);
        }
        let mut popped = 0usize;
        let mut last_at = 0u64;
        while let Some((at, _seq, _ev)) = heap.pop() {
            assert!(at >= last_at, "seed {seed}: time ran backwards ({at} < {last_at})");
            last_at = at;
            popped += 1;
        }
        assert_eq!(popped, 5000, "seed {seed}: every scheduled event pops exactly once");
    }
}

#[test]
fn same_instant_events_pop_in_schedule_order() {
    // Heavy tie pressure: only 10 distinct timestamps for 2000 events.
    // Among equal timestamps the sequence number must come out strictly
    // increasing — FIFO among ties, the bit-reproducibility keystone.
    for seed in [5u64, 9, 86] {
        let mut rng = Rng::new(seed);
        let mut heap = EventHeap::new();
        for _ in 0..2000 {
            heap.schedule(rng.below(10) as u64 * 100, ());
        }
        let mut last: Option<(u64, u64)> = None;
        while let Some((at, seq, ())) = heap.pop() {
            if let Some((prev_at, prev_seq)) = last {
                assert!(
                    at > prev_at || (at == prev_at && seq > prev_seq),
                    "seed {seed}: tie broken out of schedule order \
                     (({prev_at},{prev_seq}) then ({at},{seq}))"
                );
            }
            last = Some((at, seq));
        }
    }
}

#[test]
fn interleaved_schedule_and_pop_preserves_order() {
    // Scheduling while draining (the engine's actual access pattern:
    // every handled event schedules successors at now or later) must
    // still never pop backwards in time.
    for seed in [3u64, 21] {
        let mut rng = Rng::new(seed);
        let mut heap = EventHeap::new();
        heap.schedule(0, 0u32);
        let mut now = 0u64;
        let mut handled = 0usize;
        while let Some((at, _seq, _ev)) = heap.pop() {
            assert!(at >= now, "seed {seed}: popped {at} before {now}");
            now = at;
            handled += 1;
            if handled < 3000 {
                // One or two successors, never in the past.
                for _ in 0..1 + rng.below(2) {
                    heap.schedule(now + rng.below(500) as u64, 0u32);
                }
            }
        }
        assert!(heap.is_empty());
        assert!(handled >= 3000, "seed {seed}: the drive ran to completion");
    }
}

#[test]
fn sim_clock_is_monotone_over_any_sorted_drive() {
    for seed in [2u64, 31] {
        let mut rng = Rng::new(seed);
        let mut times: Vec<u64> = (0..1000).map(|_| rng.below(1_000_000) as u64).collect();
        times.sort_unstable();
        let clock = SimClock::new();
        let mut last_ms = 0.0f64;
        for at in times {
            clock.advance_to(at);
            let ms = clock.now_ms();
            assert!(ms >= last_ms, "seed {seed}: clock regressed");
            assert!(
                (ms - at as f64 / 1e3).abs() < 1e-9,
                "seed {seed}: now_ms disagrees with the advanced time"
            );
            last_ms = ms;
        }
    }
}

#[test]
#[should_panic(expected = "never run backwards")]
fn sim_clock_panics_on_regression() {
    let clock = SimClock::new();
    clock.advance_to(10);
    clock.advance_to(9);
}

/// A random but seed-determined multi-site scenario: 1–3 sites on
/// random variants, random pod counts, constant curves, random queue
/// bounds, quota and cache toggled at random.
fn random_scenario(seed: u64) -> DesScenario {
    let mut rng = Rng::new(0xD15C ^ seed.wrapping_mul(0x9E3779B97F4A7C15));
    let variants = ["GPU", "AGX", "ARM"];
    let nsites = 1 + rng.below(3);
    let sites: Vec<DesSite> = (0..nsites)
        .map(|i| DesSite {
            name: format!("s{i}"),
            tier: "edge".to_string(),
            variant: variants[rng.below(variants.len())].to_string(),
            pods: 1 + rng.below(2),
            arrivals: Some(RateCurve::Constant { rps: rng.range_f64(5.0, 60.0) }),
            mix: None,
        })
        .collect();
    let rtt_ms: Vec<Vec<f64>> = (0..nsites)
        .map(|i| {
            (0..nsites)
                .map(|j| if i == j { 0.0 } else { rng.range_f64(1.0, 20.0) })
                .collect()
        })
        .collect();
    let quota_on = rng.below(2) == 1;
    let cache_on = rng.below(2) == 1;
    DesScenario {
        name: format!("prop-{seed}"),
        horizon_s: 20.0,
        models: vec![
            DesModel { name: "lenet".to_string(), gflops: 0.001 },
            DesModel { name: "resnet50".to_string(), gflops: 0.168 },
        ],
        sites,
        rtt_ms,
        trace: None,
        drills: Vec::new(),
        handovers: Vec::new(),
        faults: FaultPlan::default(),
        cfg: DesConfig {
            queue_capacity: 2 + rng.below(14),
            max_batch: 1 + rng.below(8),
            quota_rps: if quota_on { rng.range_f64(5.0, 30.0) } else { 0.0 },
            quota_burst: 8.0,
            cache_ttl_ms: if cache_on { rng.range_f64(100.0, 2000.0) } else { 0.0 },
            cohorts: if cache_on { 8 } else { 0 },
            seed: seed.wrapping_add(0xACE5),
            ..DesConfig::default()
        },
    }
}

#[test]
fn randomized_scenarios_conserve_every_request() {
    for seed in 0..8u64 {
        let report = run_des(&random_scenario(seed)).unwrap();
        assert!(report.submitted > 0, "seed {seed}: load was offered");
        assert!(
            report.conservation_holds(),
            "seed {seed}: {} submitted != {} completed + {} cached + {} shed + {} quota-shed",
            report.submitted,
            report.completed,
            report.cache_hits,
            report.shed,
            report.quota_shed,
        );
    }
}

#[test]
fn randomized_scenarios_replay_byte_identically() {
    for seed in [0u64, 3, 6] {
        let first = run_des(&random_scenario(seed)).unwrap();
        let second = run_des(&random_scenario(seed)).unwrap();
        assert_eq!(
            first.canonical_json(),
            second.canonical_json(),
            "seed {seed}: the same scenario must replay to identical bytes"
        );
    }
}
