//! Integration: fused batch execution — bit-identical equivalence with
//! sequential inference, single-dispatch accounting, per-item fault
//! isolation, and the fused server batcher.
//!
//! Runs on a synthetic on-disk artifact (HLO text + empty weights), so no
//! `make artifacts` is needed: the vendored substrate executes the graph
//! shape-faithfully and counts device dispatches.

use std::fs;
use std::path::PathBuf;
use std::sync::Arc;

use tf2aif::artifact::{Artifact, Manifest};
use tf2aif::runtime::Engine;
use tf2aif::serving::{AifServer, BatcherConfig, ImageClassify, Request, ServerHandle};

/// A loadable artifact directory: ENTRY result shape `f32[1,10]`, input
/// `[1, 4, 4, 1]` (16 elements), no weight tensors.
fn synthetic_artifact(tag: &str) -> Arc<Artifact> {
    let dir: PathBuf = std::env::temp_dir()
        .join(format!("tf2aif_batch_{}_{tag}", std::process::id()));
    fs::create_dir_all(&dir).unwrap();
    fs::write(
        dir.join("model.hlo.txt"),
        "HloModule tiny\n\nENTRY main (p0: f32[1,4,4,1]) -> (f32[1,10]) {\n  \
         ROOT t = tuple()\n}\n",
    )
    .unwrap();
    fs::write(dir.join("weights.bin"), b"").unwrap();
    let manifest = Manifest {
        model: "tiny".to_string(),
        variant: "CPU".to_string(),
        platform: "x86 CPU".to_string(),
        framework: "TensorFlow Lite".to_string(),
        precision: "FP32".to_string(),
        mode: "fp32".to_string(),
        baseline_of: String::new(),
        input_shape: vec![1, 4, 4, 1],
        output_shape: vec![1, 10],
        params: Vec::new(),
        fixtures: Vec::new(),
        param_count: 0,
        weights_bytes: 0,
        master_size_mb: 0.0,
        macs: 1000,
        gflops: 0.001,
        layers: 1,
        convert_time_s: 0.0,
        lower_time_s: 0.0,
        calibration_scheme: "none".to_string(),
    };
    Arc::new(Artifact { dir, manifest })
}

#[test]
fn infer_batch_matches_sequential_infer_bit_for_bit() {
    let artifact = synthetic_artifact("equiv");
    let engine = Engine::cpu().unwrap();
    let model = engine.load(&artifact).unwrap();
    let inputs: Vec<Vec<f32>> = (0..5).map(|i| vec![i as f32 * 0.5; 16]).collect();
    let sequential: Vec<Vec<f32>> =
        inputs.iter().map(|x| model.infer(x).unwrap()).collect();
    let views: Vec<&[f32]> = inputs.iter().map(Vec::as_slice).collect();
    let fused = model.infer_batch(&views).unwrap();
    assert_eq!(fused.len(), sequential.len());
    for (f, s) in fused.iter().zip(&sequential) {
        assert_eq!(f.len(), 10);
        assert_eq!(f, s, "fused and sequential logits must be bit-identical");
    }
    // 5 sequential dispatches + exactly ONE fused dispatch for the batch.
    assert_eq!(model.dispatch_count().unwrap(), 6);
}

#[test]
fn infer_batch_validates_every_item_and_handles_empty() {
    let artifact = synthetic_artifact("validate");
    let engine = Engine::cpu().unwrap();
    let model = engine.load(&artifact).unwrap();
    let good = [0.0f32; 16];
    let bad = [0.0f32; 3];
    assert!(
        model.infer_batch(&[&good[..], &bad[..]]).is_err(),
        "a malformed item must fail the runtime-level batch"
    );
    assert_eq!(model.dispatch_count().unwrap(), 0, "rejected before dispatch");
    let empty: Vec<&[f32]> = Vec::new();
    assert!(model.infer_batch(&empty).unwrap().is_empty());
    assert_eq!(model.dispatch_count().unwrap(), 0, "empty batch touches no device");
}

#[test]
fn server_batcher_fuses_and_answers_every_request() {
    let artifact = synthetic_artifact("serve");
    let engine = Engine::cpu().unwrap();
    let server =
        Arc::new(AifServer::deploy(&engine, &artifact, Arc::new(ImageClassify)).unwrap());
    let handle =
        ServerHandle::spawn(Arc::clone(&server), BatcherConfig { max_batch: 4, workers: 2 });
    let pending: Vec<_> = (0..40)
        .map(|i| {
            handle.submit(Request { id: i, payload: vec![0.25 * (i as f32 + 1.0); 16].into() })
        })
        .collect();
    for (i, rx) in pending.into_iter().enumerate() {
        let resp = rx.recv().unwrap().unwrap();
        assert_eq!(resp.id, i as u64, "responses matched to requests across fused batches");
        assert!(resp.prediction.class < 10);
        assert!(resp.service_ms > 0.0);
    }
    handle.shutdown();
    let snap = server.metrics.snapshot();
    assert_eq!(snap.requests, 40);
    assert_eq!(snap.errors, 0);
    let dispatches = server.model.dispatch_count().unwrap();
    assert!(
        dispatches >= 1 && dispatches <= 40,
        "fused dispatches must never exceed requests, got {dispatches}"
    );
}

#[test]
fn handle_batch_isolates_malformed_items() {
    let artifact = synthetic_artifact("isolate");
    let engine = Engine::cpu().unwrap();
    let server =
        Arc::new(AifServer::deploy(&engine, &artifact, Arc::new(ImageClassify)).unwrap());
    let reqs = vec![
        Request { id: 0, payload: vec![0.1; 16].into() },
        Request { id: 1, payload: vec![0.1; 7].into() },
        Request { id: 2, payload: vec![0.2; 16].into() },
    ];
    let out = server.handle_batch(&reqs, &[0.0, 0.0, 0.0]);
    assert_eq!(out.len(), 3);
    assert!(out[0].is_ok(), "well-formed item served");
    assert!(out[1].is_err(), "malformed item fails alone");
    assert!(out[2].is_ok(), "…without poisoning the rest of the batch");
    assert_eq!(out[0].as_ref().unwrap().id, 0);
    assert_eq!(out[2].as_ref().unwrap().id, 2);
    let snap = server.metrics.snapshot();
    assert_eq!(snap.requests, 2);
    assert_eq!(snap.errors, 1);
    // The two good items rode ONE fused dispatch.
    assert_eq!(server.model.dispatch_count().unwrap(), 1);
}

#[test]
fn handle_queued_is_a_fused_batch_of_one() {
    let artifact = synthetic_artifact("single");
    let engine = Engine::cpu().unwrap();
    let server =
        Arc::new(AifServer::deploy(&engine, &artifact, Arc::new(ImageClassify)).unwrap());
    let resp = server.handle(&Request { id: 7, payload: vec![0.5; 16].into() }).unwrap();
    assert_eq!(resp.id, 7);
    assert_eq!(server.model.dispatch_count().unwrap(), 1);
    assert!(server.handle(&Request { id: 8, payload: vec![0.5; 3].into() }).is_err());
    assert_eq!(server.metrics.snapshot().errors, 1);
    assert_eq!(
        server.model.dispatch_count().unwrap(),
        1,
        "malformed single request never reaches the device"
    );
}
