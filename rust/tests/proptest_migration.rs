//! Property-style tests for live migration — randomized inputs under
//! fixed seeds (deterministic, reproducible), checking the handover
//! invariant from both directions:
//!
//! - Threaded continuum: randomized warm/in-flight/post load shapes
//!   around a live migration lose nothing — every admitted request
//!   hears exactly one terminal verdict, nothing fails, and all
//!   post-handover traffic lands on the target site.
//! - Virtual time: randomly generated mobility storms (mid-session
//!   handovers racing site flaps, random per-site demand mixes)
//!   conserve every request and replay byte-identically, and the
//!   canned `mobile-day` scenario is byte-stable under a fresh seed.

use std::collections::BTreeMap;

use tf2aif::continuum::des::canned;
use tf2aif::continuum::{
    continuum_testbed, ContinuumOrchestrator, ContinuumSubmission, PlanPolicy, RoutedRequest,
};
use tf2aif::fabric::des::{run_des, DesConfig, DesModel, DesScenario, DesSite};
use tf2aif::fabric::sim::synthetic_catalog_for;
use tf2aif::fabric::{
    AutoscaleConfig, FabricConfig, Fault, FaultPlan, Outcome, ResilienceConfig, RetryPolicy,
};
use tf2aif::util::rng::Rng;
use tf2aif::workload::{Handover, RateCurve};

/// Receive every pending outcome, asserting the exactly-once property:
/// each receiver yields one terminal verdict and then nothing.
fn recv_exactly_once(
    seed: u64,
    phase: &str,
    pending: Vec<RoutedRequest>,
    completed: &mut u64,
    shed: &mut u64,
) {
    for (i, r) in pending.into_iter().enumerate() {
        match r.rx.recv() {
            Ok(Outcome::Completed(_)) => *completed += 1,
            Ok(Outcome::Shed) => *shed += 1,
            Ok(Outcome::Failed(e)) => {
                panic!("seed {seed}: {phase} request {i} failed during migration: {e}")
            }
            Err(_) => panic!("seed {seed}: {phase} request {i} hung (sender dropped)"),
        }
        assert!(
            r.rx.try_recv().is_err(),
            "seed {seed}: {phase} request {i} must hear exactly one verdict"
        );
    }
}

#[test]
fn random_migration_drills_lose_nothing_and_verdict_exactly_once() {
    // Randomized load shapes (warm, in-flight, post-handover) and fabric
    // knobs around a live migration of the testbed's only model: the
    // conservation invariant must hold across the migration window no
    // matter how much admitted work the handover races.
    for seed in 0..5u64 {
        let mut rng = Rng::new(0x316A ^ seed.wrapping_mul(0x9E3779B97F4A7C15));
        let warm = 4 + rng.below(8) as u64;
        let inflight = 1 + rng.below(7) as u64;
        let post = 2 + rng.below(4) as u64;
        let cfg = FabricConfig {
            queue_capacity: 16 + rng.below(32),
            max_batch: 1 + rng.below(6),
            workers: 1,
            replicas_per_model: 1,
            time_scale: 0.0,
            seed: seed.wrapping_add(0x9D),
            dedup: false,
            cache_capacity: 32,
            cache_ttl_ms: 60_000,
            autoscale: Some(AutoscaleConfig {
                interval_ms: 0,
                predictive: true,
                ..Default::default()
            }),
            ..Default::default()
        };
        let mut orch = ContinuumOrchestrator::deploy_sim(
            continuum_testbed(),
            synthetic_catalog_for(&["mobilenetv1"]),
            PlanPolicy::MinLatency,
            "edge",
            &cfg,
            &BTreeMap::new(),
        )
        .expect("testbed deploys");
        let from = orch.plan().primary("mobilenetv1").expect("planned").site.clone();
        let candidates: Vec<String> = orch
            .plan()
            .ranked("mobilenetv1")
            .iter()
            .map(|p| p.site.clone())
            .filter(|s| *s != from)
            .collect();
        assert!(!candidates.is_empty(), "seed {seed}: the testbed ranks a second site");
        let to = candidates[rng.below(candidates.len())].clone();

        let mut submitted = 0u64;
        let (mut completed, mut shed) = (0u64, 0u64);
        let mut pending = Vec::new();
        for i in 0..warm {
            submitted += 1;
            match orch.submit("mobilenetv1", vec![i as f32; 16]).expect("known model") {
                ContinuumSubmission::Routed(r) => pending.push(r),
                ContinuumSubmission::Shed => shed += 1,
            }
        }
        recv_exactly_once(seed, "warm", pending, &mut completed, &mut shed);

        // Admit work and migrate BEFORE receiving: the graceful drain
        // inside the migration must complete it, never drop it.
        let mut racing = Vec::new();
        for i in 0..inflight {
            submitted += 1;
            match orch
                .submit("mobilenetv1", vec![500.0 + i as f32; 16])
                .expect("known model")
            {
                ContinuumSubmission::Routed(r) => racing.push(r),
                ContinuumSubmission::Shed => shed += 1,
            }
        }
        let rep = orch
            .migrate_model("mobilenetv1", &from, &to, "proptest drill")
            .expect("drill migration succeeds");
        assert!(
            rep.replicas_retired >= 1,
            "seed {seed}: the source must actually evacuate"
        );
        recv_exactly_once(seed, "in-flight", racing, &mut completed, &mut shed);

        let mut after = Vec::new();
        for i in 0..post {
            submitted += 1;
            match orch
                .submit("mobilenetv1", vec![900.0 + i as f32; 16])
                .expect("known model")
            {
                ContinuumSubmission::Routed(r) => {
                    assert_eq!(
                        r.site, to,
                        "seed {seed}: post-handover traffic must land on the target"
                    );
                    after.push(r);
                }
                ContinuumSubmission::Shed => shed += 1,
            }
        }
        recv_exactly_once(seed, "post", after, &mut completed, &mut shed);

        assert_eq!(
            completed + shed,
            submitted,
            "seed {seed}: zero lost admitted work across the migration window"
        );
        let last = orch.replans().last().expect("the migration records a replan event");
        assert!(
            last.reason.starts_with("migration:"),
            "seed {seed}: audit trail carries the migration trigger, got {:?}",
            last.reason
        );
        orch.shutdown();
    }
}

/// A random but seed-determined three-site scenario carrying a random
/// mobility storm: mid-session handovers between random site pairs at
/// random times, racing random site flaps, under random per-site demand
/// mixes (retry always on so flap-displaced work is re-admitted).
fn random_mobility_scenario(seed: u64) -> DesScenario {
    let mut rng = Rng::new(0x906E ^ seed.wrapping_mul(0x9E3779B97F4A7C15));
    let variants = ["GPU", "AGX", "ARM"];
    let tiers = ["cloud", "edge", "far-edge"];
    let sites: Vec<DesSite> = (0..3)
        .map(|i| DesSite {
            name: format!("s{i}"),
            tier: tiers[i].to_string(),
            variant: variants[rng.below(variants.len())].to_string(),
            pods: 1 + rng.below(2),
            arrivals: Some(RateCurve::Constant { rps: rng.range_f64(10.0, 40.0) }),
            mix: if rng.below(2) == 1 {
                Some(vec![1 + rng.below(3) as u32, 1 + rng.below(3) as u32])
            } else {
                None
            },
        })
        .collect();
    let mut handovers = Vec::new();
    for _ in 0..1 + rng.below(3) {
        let from = rng.below(3);
        let to = (from + 1 + rng.below(2)) % 3;
        handovers.push(Handover {
            at_s: rng.range_f64(2.0, 25.0),
            from: format!("s{from}"),
            to: format!("s{to}"),
        });
    }
    let mut faults = Vec::new();
    for _ in 0..1 + rng.below(2) {
        let at_s = rng.range_f64(2.0, 20.0);
        faults.push(Fault::SiteFlap {
            at_s,
            recover_s: at_s + rng.range_f64(1.0, 6.0),
            site: format!("s{}", rng.below(3)),
        });
    }
    DesScenario {
        name: format!("mobility-{seed}"),
        horizon_s: 30.0,
        models: vec![
            DesModel { name: "lenet".to_string(), gflops: 0.001 },
            DesModel { name: "resnet50".to_string(), gflops: 0.168 },
        ],
        sites,
        rtt_ms: vec![
            vec![0.0, 12.0, 25.0],
            vec![12.0, 0.0, 8.0],
            vec![25.0, 8.0, 0.0],
        ],
        trace: None,
        drills: Vec::new(),
        handovers,
        faults: FaultPlan { name: format!("mobility-plan-{seed}"), faults },
        cfg: DesConfig {
            queue_capacity: 4 + rng.below(12),
            max_batch: 1 + rng.below(6),
            resilience: ResilienceConfig {
                retry: Some(RetryPolicy::default()),
                ..Default::default()
            },
            seed: seed.wrapping_add(0x5EED),
            ..DesConfig::default()
        },
    }
}

#[test]
fn random_mobility_storms_conserve_every_request() {
    for seed in 0..6u64 {
        let sc = random_mobility_scenario(seed);
        let scheduled = sc.handovers.len() as u64;
        let report = run_des(&sc).unwrap();
        assert!(report.submitted > 0, "seed {seed}: load was offered");
        assert_eq!(
            report.handovers, scheduled,
            "seed {seed}: every scheduled handover fires"
        );
        assert!(report.faults_injected > 0, "seed {seed}: the flap plan must fire");
        assert!(
            report.conservation_holds(),
            "seed {seed}: {} submitted != {} completed + {} cached + {} shed \
             + {} quota-shed + {} failed",
            report.submitted,
            report.completed,
            report.cache_hits,
            report.shed,
            report.quota_shed,
            report.failed,
        );
    }
}

#[test]
fn random_mobility_storms_replay_byte_identically() {
    for seed in [0u64, 3, 5] {
        let first = run_des(&random_mobility_scenario(seed)).unwrap();
        let second = run_des(&random_mobility_scenario(seed)).unwrap();
        assert_eq!(
            first.canonical_json(),
            second.canonical_json(),
            "seed {seed}: the same mobility storm must replay to identical bytes"
        );
    }
}

#[test]
fn mobile_day_replays_byte_identically_under_a_fresh_seed() {
    // The golden suite pins mobile-day under its shared seed; this pins
    // it under an independent one, with the mobility and fault counters
    // asserted so the scenario can never silently degenerate into a
    // static day.
    let first = run_des(&canned("mobile-day", 23).unwrap()).unwrap();
    let second = run_des(&canned("mobile-day", 23).unwrap()).unwrap();
    assert!(first.conservation_holds(), "zero lost admitted work on the mobile day");
    assert_eq!(first.handovers, 3, "all three roaming populations move");
    assert!(first.faults_injected > 0, "the flaps race the handovers");
    assert_eq!(
        first.canonical_json(),
        second.canonical_json(),
        "mobile-day must replay byte-identically under the same seed"
    );
}
