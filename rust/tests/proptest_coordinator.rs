//! Property tests on the coordinator invariants (routing, scheduling,
//! state management) — an in-repo proptest substrate (no proptest crate in
//! the vendored set): deterministic PRNG generates random operation
//! sequences; failures print the seed for replay.

use std::collections::BTreeMap;

use tf2aif::backend::Policy;
use tf2aif::cluster::{paper_testbed, platform_needs_accelerator, Cluster, NodeSpec, PodState};
use tf2aif::config::Config;
use tf2aif::util::json::Json;
use tf2aif::util::rng::Rng;
use tf2aif::util::stats::Series;

const CASES: u64 = 200;

/// Mini property harness: run `f` across seeds, report the failing seed.
fn forall(name: &str, cases: u64, f: impl Fn(&mut Rng)) {
    for seed in 0..cases {
        let mut rng = Rng::new(0xBEEF0000 ^ seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(e) = result {
            panic!("property {name} failed at seed {seed}: {e:?}");
        }
    }
}

fn random_cluster(rng: &mut Rng) -> Cluster {
    let n_nodes = 1 + rng.below(5);
    let all_platforms = ["AGX", "ARM", "CPU", "ALVEO", "GPU"];
    let nodes = (0..n_nodes)
        .map(|i| {
            let k = 1 + rng.below(3);
            let mut plats: Vec<String> = Vec::new();
            for _ in 0..k {
                let p = all_platforms[rng.below(5)].to_string();
                if !plats.contains(&p) {
                    plats.push(p);
                }
            }
            let arm = plats.iter().any(|p| p == "ARM" || p == "AGX");
            NodeSpec {
                name: format!("n{i}"),
                arch: if arm { "arm64".into() } else { "x86_64".into() },
                cpu_desc: String::new(),
                cpus: 4 + rng.below(16),
                memory_gb: 2.0 + rng.f64() * 30.0,
                accelerator: "sim".into(),
                platforms: plats,
                slots: 1 + rng.below(3),
            }
        })
        .collect();
    let mut c = Cluster::new(nodes);
    c.apply_kube_api_extension();
    c
}

/// INVARIANT: whatever sequence of bind/terminate/fail ops runs, per-node
/// accelerator slots and memory are never over-committed, and feasibility
/// always implies a successful bind.
#[test]
fn prop_scheduler_never_overcommits() {
    forall("scheduler_never_overcommits", CASES, |rng| {
        let mut cluster = random_cluster(rng);
        let variants = ["AGX", "ARM", "CPU", "ALVEO", "GPU", "CPU_TF", "GPU_TF"];
        let mut live: Vec<u64> = Vec::new();
        for step in 0..30 {
            let roll = rng.f64();
            if roll < 0.6 {
                let v = variants[rng.below(variants.len())];
                let mem = 0.1 + rng.f64() * 8.0;
                let feasible: Vec<String> =
                    cluster.feasible_nodes(v, mem).iter().map(|n| n.name.clone()).collect();
                if let Some(node) = feasible.first() {
                    let id = cluster
                        .bind(&format!("aif{step}"), v, node, mem)
                        .expect("feasible bind must succeed");
                    live.push(id);
                }
            } else if roll < 0.85 {
                if !live.is_empty() {
                    let id = live.swap_remove(rng.below(live.len()));
                    cluster.terminate(id).expect("terminate running pod");
                }
            } else if !live.is_empty() {
                let id = live.swap_remove(rng.below(live.len()));
                cluster.fail(id).expect("fail running pod");
            }

            // Check global invariants after every step.
            let mut slots: BTreeMap<&str, usize> = BTreeMap::new();
            let mut mem: BTreeMap<&str, f64> = BTreeMap::new();
            for p in cluster.pods().iter().filter(|p| p.state == PodState::Running) {
                if platform_needs_accelerator(&p.variant) {
                    *slots.entry(p.node.as_str()).or_default() += 1;
                }
                *mem.entry(p.node.as_str()).or_default() += p.memory_gb;
            }
            for n in cluster.nodes() {
                assert!(
                    slots.get(n.name.as_str()).copied().unwrap_or(0) <= n.slots,
                    "slot overcommit on {}",
                    n.name
                );
                assert!(
                    mem.get(n.name.as_str()).copied().unwrap_or(0.0) <= n.memory_gb + 1e-9,
                    "memory overcommit on {}",
                    n.name
                );
            }
        }
    });
}

/// INVARIANT: feasible_nodes is exactly the set on which bind succeeds.
#[test]
fn prop_feasibility_matches_bind() {
    forall("feasibility_matches_bind", CASES, |rng| {
        let mut cluster = random_cluster(rng);
        // Random pre-load.
        for i in 0..rng.below(6) {
            let v = ["AGX", "CPU", "GPU"][rng.below(3)];
            let nodes: Vec<String> =
                cluster.feasible_nodes(v, 1.0).iter().map(|n| n.name.clone()).collect();
            if let Some(n) = nodes.first() {
                cluster.bind(&format!("pre{i}"), v, n, 1.0).unwrap();
            }
        }
        let v = ["AGX", "ARM", "CPU", "ALVEO", "GPU"][rng.below(5)];
        let mem = 0.5 + rng.f64() * 4.0;
        let feasible: Vec<String> =
            cluster.feasible_nodes(v, mem).iter().map(|n| n.name.clone()).collect();
        let node_names: Vec<String> =
            cluster.nodes().iter().map(|n| n.name.clone()).collect();
        for name in node_names {
            let ok = cluster.bind("probe", v, &name, mem).is_ok();
            assert_eq!(
                ok,
                feasible.contains(&name),
                "bind({v},{name}) disagrees with feasibility"
            );
            if ok {
                // Roll back so each probe sees the same state.
                let id = cluster
                    .pods()
                    .iter()
                    .rev()
                    .find(|p| p.aif == "probe" && p.state == PodState::Running)
                    .unwrap()
                    .id;
                cluster.terminate(id).unwrap();
            }
        }
    });
}

/// INVARIANT: backend ranking is sorted by score and deterministic.
#[test]
fn prop_backend_ranking_sorted_deterministic() {
    let Ok(artifacts) = tf2aif::artifact::scan("artifacts") else { return };
    if artifacts.is_empty() {
        return;
    }
    forall("backend_ranking", 40, |rng| {
        let cluster = {
            let mut c = Cluster::new(paper_testbed());
            c.apply_kube_api_extension();
            c
        };
        let policy = [Policy::MinLatency, Policy::PreferEdge, Policy::MinEnergy]
            [rng.below(3)];
        let backend = tf2aif::backend::Backend::new(
            tf2aif::artifact::scan("artifacts").unwrap(),
            policy,
        );
        let model = ["lenet", "mobilenetv1", "resnet50", "inceptionv4"][rng.below(4)];
        let r1 = backend.rank(model, &cluster).unwrap();
        let r2 = backend.rank(model, &cluster).unwrap();
        assert_eq!(r1.len(), r2.len());
        for (a, b) in r1.iter().zip(&r2) {
            assert_eq!(a.variant, b.variant);
            assert_eq!(a.node, b.node);
        }
        for w in r1.windows(2) {
            assert!(w[0].score <= w[1].score, "ranking not sorted");
        }
    });
}

/// INVARIANT: JSON round-trips arbitrary values built from our generators.
#[test]
fn prop_json_roundtrip() {
    fn gen_value(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.f64() < 0.5),
            2 => Json::Num((rng.f64() * 2e6).round() / 2.0 - 5e5),
            3 => {
                let n = rng.below(12);
                Json::Str(
                    (0..n)
                        .map(|_| {
                            let chars = ['a', 'Z', '9', '"', '\\', '\n', 'é', '\t', ' '];
                            chars[rng.below(chars.len())]
                        })
                        .collect(),
                )
            }
            4 => Json::Arr((0..rng.below(5)).map(|_| gen_value(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.below(5))
                    .map(|i| (format!("k{i}"), gen_value(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    forall("json_roundtrip", 500, |rng| {
        let v = gen_value(rng, 3);
        let s = v.to_string();
        let back = Json::parse(&s).unwrap_or_else(|e| panic!("parse {s:?}: {e}"));
        assert_eq!(v, back, "roundtrip mismatch for {s:?}");
    });
}

/// INVARIANT: percentile() agrees with a naive reference implementation.
#[test]
fn prop_percentile_matches_reference() {
    forall("percentile_reference", 300, |rng| {
        let n = 1 + rng.below(200);
        let xs: Vec<f64> = (0..n).map(|_| rng.normal() * 10.0).collect();
        let mut series = Series::new();
        series.extend(xs.iter().copied());
        let mut sorted = xs.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for p in [0.0, 10.0, 25.0, 50.0, 75.0, 90.0, 100.0] {
            let got = series.percentile(p);
            // R-7 reference.
            let rank = p / 100.0 * (n - 1) as f64;
            let lo = rank.floor() as usize;
            let hi = rank.ceil() as usize;
            let frac = rank - lo as f64;
            let want = sorted[lo] * (1.0 - frac) + sorted[hi.min(n - 1)] * frac;
            assert!((got - want).abs() < 1e-9, "p{p}: {got} vs {want}");
        }
        // Monotonicity.
        let mut prev = f64::NEG_INFINITY;
        for p in 0..=20 {
            let v = series.percentile(p as f64 * 5.0);
            assert!(v >= prev - 1e-12);
            prev = v;
        }
    });
}

/// INVARIANT: the config parser accepts what it emits conceptually —
/// values written in TOML-subset syntax parse back to the same values.
#[test]
fn prop_config_values_roundtrip() {
    forall("config_roundtrip", 300, |rng| {
        let n = rng.below(8);
        let mut src = String::new();
        let mut expect: Vec<(String, f64)> = Vec::new();
        for i in 0..n {
            let v = (rng.f64() * 1e4).round() / 4.0;
            src.push_str(&format!("key{i} = {v}\n"));
            expect.push((format!("key{i}"), v));
        }
        let cfg = Config::parse(&src).unwrap();
        for (k, v) in expect {
            assert_eq!(cfg.root.get(&k).unwrap().f64().unwrap(), v);
        }
    });
}

/// INVARIANT: terminated/failed pods never come back; ids never reused.
#[test]
fn prop_pod_lifecycle_is_monotone() {
    forall("pod_lifecycle", CASES, |rng| {
        let mut cluster = random_cluster(rng);
        let mut seen: Vec<u64> = Vec::new();
        for i in 0..20 {
            let v = ["CPU", "GPU", "AGX"][rng.below(3)];
            let nodes: Vec<String> =
                cluster.feasible_nodes(v, 0.5).iter().map(|n| n.name.clone()).collect();
            if let Some(node) = nodes.first() {
                let id = cluster.bind(&format!("a{i}"), v, node, 0.5).unwrap();
                assert!(!seen.contains(&id), "pod id reuse");
                seen.push(id);
                if rng.f64() < 0.5 {
                    cluster.terminate(id).unwrap();
                    assert!(cluster.terminate(id).is_err(), "double terminate");
                    assert!(cluster.fail(id).is_err(), "fail after terminate");
                }
            }
        }
    });
}
