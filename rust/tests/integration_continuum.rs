//! Integration: the continuum orchestrator — a 3-site topology serving
//! a mixed workload, spillover past a saturated preferred site,
//! mid-stream site loss with zero silent drops, and the measurable
//! energy/latency divergence between planning policies.
//!
//! Everything runs on simulated pods (synthetic catalog + platform cost
//! models) over the built-in testbed; the failure drills reuse the
//! deterministic scenario driver (`continuum::run_scenarios`), the same
//! code behind the `tf2aif bench` v4 verdicts CI gates on.

use std::collections::BTreeMap;
use std::sync::Arc;

use tf2aif::continuum::{
    self, continuum_testbed, ContinuumOrchestrator, ContinuumSubmission, PlanPolicy, Planner,
};
use tf2aif::fabric::sim::{synthetic_catalog, synthetic_catalog_for, Gate};
use tf2aif::fabric::{FabricConfig, Outcome};
use tf2aif::workload::{Arrival, TenantMix};

fn sim_cfg() -> FabricConfig {
    FabricConfig {
        queue_capacity: 32,
        max_batch: 4,
        workers: 1,
        replicas_per_model: 1,
        time_scale: 0.0,
        dedup: false,
        cache_capacity: 0,
        ..Default::default()
    }
}

fn mixed_orchestrator(policy: PlanPolicy) -> ContinuumOrchestrator {
    ContinuumOrchestrator::deploy_sim(
        continuum_testbed(),
        synthetic_catalog(),
        policy,
        "edge",
        &sim_cfg(),
        &BTreeMap::new(),
    )
    .expect("testbed deploys")
}

fn even_mix(orch: &ContinuumOrchestrator) -> TenantMix {
    let entries: Vec<(String, u32)> =
        orch.plan().models().iter().map(|m| (m.to_string(), 1)).collect();
    TenantMix::new(&entries).unwrap()
}

#[test]
fn three_site_topology_serves_a_mixed_workload() {
    let mut orch = mixed_orchestrator(PlanPolicy::MinLatency);
    assert_eq!(orch.active_sites().len(), 3, "all three sites host something");
    assert_eq!(orch.plan().models().len(), 4, "all Table III models planned");
    let mix = even_mix(&orch);
    let run = orch.run(120, Arrival::Poisson { rps: 2000.0 }, 11, &mix, None).unwrap();
    assert!(run.fully_accounted(), "{run:?}");
    assert_eq!(run.failed, 0);
    assert!(run.completed > 0);
    assert_eq!(run.e2e_ms.len(), run.completed);
    // Per-site rows cover every active site; energy accounting is live
    // wherever requests were served.
    assert_eq!(run.per_site.len(), 3);
    let served: u64 = run.per_site.iter().map(|s| s.completed).sum();
    assert!(served >= run.completed as u64, "sites served at least the run's completions");
    for site in &run.per_site {
        assert!(!site.lost);
        if site.completed > 0 {
            assert!(site.energy.j_per_request > 0.0, "{site:?}");
            assert!(site.energy.mean_utilization >= 0.0);
        }
    }
    orch.shutdown();
}

#[test]
fn killing_the_preferred_edge_site_mid_stream_replans_without_drops() {
    let mut orch = mixed_orchestrator(PlanPolicy::MinLatency);
    // With demand at the edge, the edge site is the preferred home for
    // at least one model.
    let before: Vec<String> = orch
        .plan()
        .models()
        .iter()
        .filter(|m| orch.plan().primary(m).unwrap().site == "edge")
        .map(|m| m.to_string())
        .collect();
    assert!(!before.is_empty(), "edge is someone's preferred site");
    let mix = even_mix(&orch);
    let run = orch
        .run(160, Arrival::Poisson { rps: 4000.0 }, 13, &mix, Some((80, "edge")))
        .unwrap();
    // Zero silent drops: every submission has an explicit outcome and
    // nothing failed — admitted work on the dying site drained to
    // completion before the replan.
    assert!(run.fully_accounted(), "{run:?}");
    assert_eq!(run.failed, 0, "graceful site loss never fails admitted work");
    assert!(run.completed > 0);
    // The replan happened, moved the edge-primaried models, and the
    // takeover sites are next-ranked survivors.
    assert_eq!(orch.replans().len(), 1);
    let moved = &orch.replans()[0].moved;
    for model in &before {
        assert!(
            moved.iter().any(|(m, from, _)| m == model && from == "edge"),
            "{model} must have moved off the dead site: {moved:?}"
        );
    }
    for model in orch.plan().models() {
        let p = orch.plan().primary(model).unwrap();
        assert_ne!(p.site, "edge", "{model} still primaried on the dead site");
    }
    // The frozen edge row is in the report; survivors carry the load.
    let rows = run.per_site.clone();
    let edge = rows.iter().find(|s| s.site == "edge").expect("frozen row survives");
    assert!(edge.lost);
    let survivors: u64 =
        rows.iter().filter(|s| !s.lost).map(|s| s.completed).sum();
    assert!(survivors > 0, "post-loss traffic lands on surviving sites");
    orch.shutdown();
}

#[test]
fn spillover_lands_on_the_next_ranked_site_and_recovers() {
    // Gate the preferred (edge) site shut and flood: the surplus must
    // spill to the next-ranked site, complete there, and be fully
    // accounted.  (The bench verdict `spillover_recovers` runs this
    // same drill through the scenario driver.)
    let gate = Gate::closed_gate();
    let mut gates = BTreeMap::new();
    gates.insert("edge".to_string(), Arc::clone(&gate));
    let mut orch = ContinuumOrchestrator::deploy_sim(
        continuum_testbed(),
        synthetic_catalog_for(&["mobilenetv1"]),
        PlanPolicy::MinLatency,
        "edge",
        &FabricConfig { queue_capacity: 4, ..sim_cfg() },
        &gates,
    )
    .unwrap();
    assert_eq!(orch.plan().primary("mobilenetv1").unwrap().site, "edge");
    let next_ranked = orch.plan().ranked("mobilenetv1")[1].site.clone();
    let mut pending = Vec::new();
    let mut continuum_shed = 0u64;
    for i in 0..24 {
        match orch.submit("mobilenetv1", vec![i as f32; 16]).unwrap() {
            ContinuumSubmission::Routed(r) => pending.push(r),
            ContinuumSubmission::Shed => continuum_shed += 1,
        }
    }
    let spilled = pending.iter().filter(|r| r.spilled).count();
    assert!(spilled > 0, "a 24-deep flood into a gated 4-deep site must spill");
    assert!(
        pending.iter().any(|r| r.spilled && r.site == next_ranked),
        "spillover prefers the next-ranked site {next_ranked}"
    );
    gate.open();
    let mut completed_spilled = 0;
    let mut accounted = continuum_shed as usize;
    for r in pending {
        match r.rx.recv().unwrap() {
            Outcome::Completed(_) => {
                accounted += 1;
                if r.spilled {
                    completed_spilled += 1;
                }
            }
            Outcome::Shed => accounted += 1,
            Outcome::Failed(e) => panic!("unexpected failure: {e}"),
        }
    }
    assert_eq!(accounted, 24, "every submission explicitly accounted");
    assert!(completed_spilled > 0, "spilled traffic completes on the fallback site");
    orch.shutdown();
}

#[test]
fn energy_and_latency_policies_measurably_differ() {
    // The acceptance criterion: min-energy vs min-latency plans differ
    // in modeled joules/request, with the latency delta reported.
    let catalog = synthetic_catalog();
    let lat = Planner::new(continuum_testbed(), catalog.clone(), PlanPolicy::MinLatency, "edge")
        .unwrap()
        .plan()
        .unwrap();
    let nrg = Planner::new(continuum_testbed(), catalog, PlanPolicy::MinEnergy, "edge")
        .unwrap()
        .plan()
        .unwrap();
    let (lat_j, nrg_j) = (lat.mean_energy_j(), nrg.mean_energy_j());
    let (lat_ms, nrg_ms) = (lat.mean_latency_ms(), nrg.mean_latency_ms());
    assert!(
        nrg_j <= 0.9 * lat_j,
        "min-energy must save measurably: {nrg_j:.4} vs {lat_j:.4} J/request"
    );
    let delta_ms = nrg_ms - lat_ms;
    assert!(
        delta_ms >= 0.0,
        "the energy saving costs (or at worst matches) latency: delta {delta_ms:.2} ms"
    );
}

#[test]
fn scenario_driver_verdicts_hold_and_reproduce() {
    let a = continuum::run_scenarios(42);
    assert!(a.spillover_recovers, "{a:?}");
    assert!(a.replan_no_drop, "{a:?}");
    assert!(a.energy_policy_tradeoff, "{a:?}");
    // The planner-level numbers are deterministic (the fabric-level
    // spill counts depend on drain timing and may vary run to run).
    let b = continuum::run_scenarios(42);
    assert_eq!(a.min_latency_energy_j, b.min_latency_energy_j);
    assert_eq!(a.min_energy_energy_j, b.min_energy_energy_j);
    assert_eq!(a.min_latency_ms, b.min_latency_ms);
    assert_eq!(a.min_energy_ms, b.min_energy_ms);
    assert_eq!(a.replan_moves, b.replan_moves);
}

#[test]
fn drain_node_replans_around_the_cordoned_node() {
    let mut orch = mixed_orchestrator(PlanPolicy::MinLatency);
    // NE-2 hosts the edge V100 — draining it must move every placement
    // off that node in the refreshed plan.
    orch.drain_node("edge", "NE-2").unwrap();
    assert_eq!(orch.replans().len(), 1);
    for model in orch.plan().models() {
        for p in orch.plan().ranked(model) {
            assert!(
                !(p.site == "edge" && (p.node == "NE-2" || p.nodes.iter().any(|n| n == "NE-2"))),
                "{model} still planned on the drained node: {p:?}"
            );
        }
    }
    // Unknown sites and nodes are typed errors.
    assert!(orch.drain_node("nowhere", "NE-2").is_err());
    assert!(orch.drain_node("edge", "ghost").is_err());
    // Traffic still flows after the replan.
    let mix = even_mix(&orch);
    let run = orch.run(40, Arrival::ClosedLoop, 3, &mix, None).unwrap();
    assert!(run.fully_accounted());
    assert_eq!(run.failed, 0);
    orch.shutdown();
}
