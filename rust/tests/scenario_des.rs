//! Golden suite for the discrete-event simulation core: every canned
//! continuum scenario, replayed on the virtual clock, must be
//! **bit-reproducible** — the same scenario under the same seed twice
//! produces byte-identical canonical reports — while different seeds
//! must produce different reports (determinism is not degeneracy).
//! Request conservation (`submitted = completed + cache_hits + shed +
//! quota_shed`, globally and per origin site) is asserted on every run,
//! and the canonical report must parse back with the documented schema
//! fields.  The million-user day is exercised by the CI determinism
//! gate through the release CLI (`tf2aif continuum --virtual-time`);
//! tier-1 covers the three fast scenarios, and
//! `million_user_day_golden_is_byte_stable` pins the full day — ignored
//! under debug builds, live in the release golden-suite CI step.

use tf2aif::continuum::des::{canned, scenario_from_topology, CANNED};
use tf2aif::continuum::continuum_testbed;
use tf2aif::fabric::des::{run_des, DesConfig};
use tf2aif::util::json::Json;
use tf2aif::workload::TraceEvent;

/// The canned scenarios cheap enough for the debug-build golden suite.
const GOLDEN: &[&str] = &["diurnal-day", "flash-crowd", "site-loss-storm", "mobile-day"];

#[test]
fn canned_registry_builds_every_scenario() {
    for name in CANNED {
        let sc = canned(name, 3).expect("canned scenario builds");
        assert_eq!(sc.name, *name);
        assert_eq!(sc.sites.len(), 3, "{name}: built on the 3-site testbed");
    }
    assert!(canned("no-such-scenario", 3).is_err());
}

#[test]
fn golden_scenarios_are_bit_reproducible_under_the_same_seed() {
    for name in GOLDEN {
        let first = run_des(&canned(name, 11).unwrap()).unwrap();
        let second = run_des(&canned(name, 11).unwrap()).unwrap();
        assert!(first.conservation_holds(), "{name}: conservation");
        assert!(first.submitted > 0, "{name}: the scenario offers load");
        assert_eq!(
            first.canonical_json(),
            second.canonical_json(),
            "{name}: same seed twice must be byte-identical"
        );
    }
}

#[test]
fn different_seeds_change_the_golden_report() {
    let a = run_des(&canned("diurnal-day", 11).unwrap()).unwrap();
    let b = run_des(&canned("diurnal-day", 12).unwrap()).unwrap();
    assert!(a.conservation_holds() && b.conservation_holds());
    assert_ne!(
        a.canonical_json(),
        b.canonical_json(),
        "the seed must actually steer arrivals and service sampling"
    );
}

#[test]
fn trace_replay_is_deterministic_and_conserves() {
    // A hand-built 600-request trace alternating origin sites: replay
    // is exact (submitted = trace length), deterministic, and with
    // quota/cache off every request is either completed or shed.
    let trace: Vec<TraceEvent> = (0..600)
        .map(|i| TraceEvent {
            at_ms: i as f64 * 5.0,
            site: ["cloud", "edge", "far-edge"][i % 3].to_string(),
            model: "lenet".to_string(),
        })
        .collect();
    let build = || {
        let mut sc = scenario_from_topology(
            "trace-replay",
            &continuum_testbed(),
            &["lenet"],
            DesConfig { seed: 77, ..DesConfig::default() },
        )
        .unwrap();
        sc.trace = Some(trace.clone());
        sc
    };
    let first = run_des(&build()).unwrap();
    let second = run_des(&build()).unwrap();
    assert_eq!(first.submitted, 600, "every trace row is offered exactly once");
    assert!(first.conservation_holds());
    assert_eq!(first.cache_hits, 0, "cache is off in the default config");
    assert_eq!(first.quota_shed, 0, "quota is off in the default config");
    assert_eq!(first.submitted, first.completed + first.shed);
    assert_eq!(first.canonical_json(), second.canonical_json());
}

#[test]
fn storm_injects_faults_and_loses_no_admitted_work() {
    // The load-bearing chaos invariant at tier-1: the canned storm's
    // fault plan actually fires, and every admitted request still
    // reaches exactly one terminal verdict (request conservation holds
    // globally and per site).  Replay is byte-identical under the same
    // seed even with crashes, stragglers, partitions and flaps racing
    // the replanner.
    let first = run_des(&canned("site-loss-storm", 19).unwrap()).unwrap();
    let second = run_des(&canned("site-loss-storm", 19).unwrap()).unwrap();
    assert!(first.faults_injected > 0, "the storm's fault plan must fire");
    assert!(first.conservation_holds(), "zero lost admitted work under the storm");
    assert_eq!(
        first.canonical_json(),
        second.canonical_json(),
        "the storm replays byte-identically under the same seed"
    );
    // The resilience section is part of the canonical schema.
    let doc = Json::parse(&first.canonical_json()).unwrap();
    let res = doc.get("resilience").unwrap();
    for key in [
        "hedges_launched",
        "hedges_won",
        "hedges_lost",
        "breaker_trips",
        "breakers_open_end",
        "brownout_ms",
        "faults_injected",
    ] {
        assert!(res.get(key).unwrap().f64().unwrap() >= 0.0, "resilience.{key}");
    }
    assert_eq!(
        res.get("faults_injected").unwrap().usize().unwrap() as u64,
        first.faults_injected,
        "the canonical report mirrors the in-memory counter"
    );
}

#[test]
#[cfg_attr(debug_assertions, ignore = "1.29M virtual requests: release builds only")]
fn million_user_day_golden_is_byte_stable() {
    // The acceptance drive itself, pinned in-suite: after the hot-path
    // rework (sharded registry snapshots, two-tier dedup hashing,
    // `Arc<[f32]>` payloads) the million-user day must still replay to
    // the byte.  The DES engine is payload-free, so any drift here means
    // the fabric changes leaked into the virtual-time path.
    let first = run_des(&canned("million-user-day", 11).unwrap()).unwrap();
    let second = run_des(&canned("million-user-day", 11).unwrap()).unwrap();
    assert!(first.submitted > 1_000_000, "the day really offers a million users");
    assert!(first.conservation_holds(), "every virtual request reaches a verdict");
    assert_eq!(
        first.canonical_json(),
        second.canonical_json(),
        "million-user-day canonical report must be byte-identical run to run"
    );
}

#[test]
fn canonical_report_parses_with_schema_fields() {
    let report = run_des(&canned("site-loss-storm", 4).unwrap()).unwrap();
    let doc = Json::parse(&report.canonical_json()).expect("canonical JSON parses");
    assert_eq!(doc.get("scenario").unwrap().str().unwrap(), "site-loss-storm");
    assert_eq!(doc.get("seed").unwrap().usize().unwrap(), 4);
    assert!(doc.get("events").unwrap().usize().unwrap() > 0);
    assert!(doc.get("submitted").unwrap().usize().unwrap() > 0);
    assert!(matches!(doc.get("conservation").unwrap(), Json::Bool(true)));
    let lat = doc.get("latency_ms").unwrap();
    for key in ["p50", "p99", "mean", "max"] {
        assert!(lat.get(key).unwrap().f64().unwrap() >= 0.0, "latency_ms.{key}");
    }
    let sites = doc.get("sites").unwrap().arr().unwrap();
    assert_eq!(sites.len(), 3);
    for row in sites {
        for key in ["site", "tier", "variant"] {
            assert!(!row.get(key).unwrap().str().unwrap().is_empty(), "sites[].{key}");
        }
        assert!(row.get("pods_end").unwrap().usize().unwrap() >= 1);
    }
}
