//! Integration: the cluster-scale serving fabric — placement across the
//! paper testbed, routed traffic, deterministic load-shedding at the
//! admission bound, full request accounting, and the measurement→
//! placement feedback loop.
//!
//! Runs entirely on the simulated executors (synthetic catalog + platform
//! cost models), so no `make artifacts` is needed.

use std::collections::BTreeSet;
use std::sync::Arc;

use tf2aif::backend::{Backend, Policy};
use tf2aif::cluster::{paper_testbed, Cluster};
use tf2aif::fabric::sim::{synthetic_catalog, Gate};
use tf2aif::fabric::{Fabric, FabricConfig, Outcome, Submission};
use tf2aif::metrics::FeedbackStore;
use tf2aif::workload::Arrival;

fn testbed() -> Cluster {
    let mut c = Cluster::new(paper_testbed());
    c.apply_kube_api_extension();
    c
}

fn place(cfg: &FabricConfig, gate: Option<Arc<Gate>>) -> Fabric {
    let backend = Backend::new(synthetic_catalog(), Policy::MinLatency);
    Fabric::place_sim(&backend, testbed(), cfg, gate).unwrap()
}

#[test]
fn fleet_spans_all_three_testbed_nodes() {
    let cfg = FabricConfig { time_scale: 0.0, ..Default::default() };
    let fabric = place(&cfg, None);
    let nodes = fabric.nodes_spanned();
    for n in ["NE-1", "NE-2", "FE"] {
        assert!(nodes.contains(n), "fleet missing node {n}: {nodes:?}");
    }
    // Every model got at least one pod, none more than the replica cap,
    // and replica nodes are distinct.
    for model in fabric.models() {
        let pods: Vec<_> =
            fabric.plans().into_iter().filter(|p| p.model == model).collect();
        assert!(!pods.is_empty(), "{model} unplaced");
        assert!(pods.len() <= cfg.replicas_per_model);
        let distinct: BTreeSet<_> = pods.iter().map(|p| p.node.clone()).collect();
        assert_eq!(distinct.len(), pods.len(), "{model} replicas share a node");
    }
    fabric.shutdown();
}

#[test]
fn poisson_overload_routes_across_nodes_and_accounts_every_request() {
    // Small queues + full-latency simulated pods: the Poisson burst
    // builds real backlog, so the least-estimated-work router must spill
    // every model onto its 2nd and 3rd replicas (backlog multiplies each
    // pod's score) before shedding.
    let cfg = FabricConfig {
        queue_capacity: 2,
        max_batch: 2,
        workers: 1,
        // 5× the modeled latency really slept: drain (≈0.7k rps/model)
        // is far below the offered load, so queues must overflow.
        time_scale: 5.0,
        ..Default::default()
    };
    let fabric = place(&cfg, None);
    let run = fabric.run(400, Arrival::Poisson { rps: 50_000.0 }, 9).unwrap();
    assert!(run.fully_accounted(), "completed+failed+shed must equal submitted");
    assert_eq!(run.failed, 0, "simulated pods never fail");
    assert!(run.completed > 0);
    assert!(run.shed > 0, "sustained overload of bounded queues must shed");
    // Backlog-aware routing reached the whole testbed.
    let reports = fabric.pod_reports(run.wall_s);
    let busy_nodes: BTreeSet<_> = reports
        .iter()
        .filter(|r| r.requests > 0)
        .map(|r| r.node.clone())
        .collect();
    assert!(busy_nodes.len() >= 3, "traffic only reached {busy_nodes:?}");
    // Under overload the fused batcher must have amortized: strictly
    // fewer dispatches than served requests somewhere in the fleet.
    let served: u64 = reports.iter().map(|r| r.requests).sum();
    let dispatches: u64 = reports.iter().map(|r| r.dispatches).sum();
    assert!(dispatches > 0 && dispatches < served, "{dispatches} vs {served}");
    assert!(
        reports.iter().any(|r| r.avg_batch > 1.0),
        "overloaded pods must report avg batch > 1"
    );
    // Fleet aggregate matches the run accounting.
    let fleet = fabric.fleet_report(run.wall_s);
    assert_eq!(fleet.requests as usize, run.completed);
    assert_eq!(fleet.shed as usize, run.shed);
    assert!(fleet.service.is_some());
    fabric.shutdown();
}

#[test]
fn shedding_kicks_in_exactly_at_the_admission_bound() {
    // Gate the executors closed so nothing drains, then flood one model.
    // Deterministic capacity while gated: every replica queue holds
    // `queue_capacity`, and each worker can hold one in-flight batch of
    // up to `max_batch` requests it popped before blocking on the gate.
    let cfg = FabricConfig {
        queue_capacity: 8,
        max_batch: 4,
        workers: 1,
        time_scale: 0.0,
        // The flood reuses one payload; dedup would collapse it into a
        // single execution — this test is about the admission bound.
        dedup: false,
        ..Default::default()
    };
    let gate = Gate::closed_gate();
    let fabric = place(&cfg, Some(Arc::clone(&gate)));
    let model = "lenet";
    let replicas = fabric
        .plans()
        .into_iter()
        .filter(|p| p.model == model)
        .count();
    assert!(replicas >= 2, "need sharded replicas for this test");
    let max_admitted = replicas * (cfg.queue_capacity + cfg.workers * cfg.max_batch);

    let flood = max_admitted + 50;
    let mut pending = Vec::new();
    let mut shed = 0usize;
    for _ in 0..flood {
        match fabric.submit(model, vec![0.0; 4]).unwrap() {
            Submission::Enqueued(rx) => pending.push(rx),
            Submission::Shed => shed += 1,
        }
    }
    assert!(shed >= 50, "flood past the bound must shed, got {shed}");
    assert!(
        pending.len() <= max_admitted,
        "admitted {} > deterministic bound {max_admitted}",
        pending.len()
    );
    assert_eq!(pending.len() + shed, flood, "no request may vanish at submit");
    assert_eq!(fabric.shed_total() as usize, shed);
    assert_eq!(fabric.shed_by_model().get(model).copied().unwrap_or(0) as usize, shed);

    // Open the gate: every admitted request must complete — shedding is
    // explicit, never a silent drop.
    gate.open();
    let mut completed = 0usize;
    for rx in pending {
        match rx.recv().expect("worker must answer every admitted request") {
            Outcome::Completed(resp) => {
                completed += 1;
                assert!(resp.service_ms > 0.0);
            }
            Outcome::Failed(e) => panic!("unexpected failure: {e}"),
            Outcome::Shed => panic!("uniform priority never preempts admitted work"),
        }
    }
    assert_eq!(completed + shed, flood);
    fabric.shutdown();
}

#[test]
fn measured_latency_feeds_back_into_placement_scores() {
    let cfg = FabricConfig { time_scale: 0.0, ..Default::default() };
    let fabric = place(&cfg, None);
    let run = fabric.run(200, Arrival::ClosedLoop, 5).unwrap();
    assert!(run.completed > 0);

    // The store the fabric filled re-scores a backend's rankings.
    let store = fabric.feedback();
    assert!(!store.all().is_empty());
    let mut backend = Backend::new(synthetic_catalog(), Policy::MinLatency);
    backend.feedback = Some(Arc::clone(&store));
    let cluster = testbed();
    let mut observed_placements = 0usize;
    for d in backend.rank("inceptionv4", &cluster).unwrap() {
        let key = FeedbackStore::key(&d.aif, &d.node);
        match store.get(&key) {
            Some(fb) => {
                observed_placements += 1;
                // rank must have plumbed exactly the store's blend in.
                let expect = store.blend(&key, d.modeled_ms);
                assert!(
                    (d.estimated_ms - expect).abs() < 1e-9,
                    "{key}: estimated {} != blend {expect}",
                    d.estimated_ms
                );
                // With a real measurement the estimate must have moved
                // off the pure cost model (noise makes ties a.s. absent).
                if (fb.ewma_service_ms - d.modeled_ms).abs() > 1e-9 {
                    assert_ne!(d.estimated_ms, d.modeled_ms, "{key}: feedback ignored");
                }
            }
            None => assert_eq!(d.estimated_ms, d.modeled_ms, "no obs → pure model"),
        }
        assert!(d.estimated_ms.is_finite());
    }
    assert!(
        observed_placements > 0,
        "routed traffic must have produced observations for ranked placements"
    );
    fabric.shutdown();
}

#[test]
fn queue_bound_sheds_under_sustained_overload_then_recovers() {
    // Slow pods (time_scale 1.0 → real sleeps at full modeled latency)
    // and tiny queues: an instantaneous burst must shed; after draining,
    // a trickle must be admitted again.
    let cfg = FabricConfig {
        queue_capacity: 2,
        max_batch: 1,
        workers: 1,
        replicas_per_model: 1,
        time_scale: 1.0,
        // Identical burst payloads: dedup off, this test is about
        // shedding and recovery at the admission bound.
        dedup: false,
        ..Default::default()
    };
    let fabric = place(&cfg, None);
    let model = "inceptionv4";
    let mut pending = Vec::new();
    let mut shed = 0usize;
    for _ in 0..64 {
        match fabric.submit(model, vec![0.0; 4]).unwrap() {
            Submission::Enqueued(rx) => pending.push(rx),
            Submission::Shed => shed += 1,
        }
    }
    assert!(shed > 0, "64-deep instantaneous burst into a 2-deep queue must shed");
    for rx in pending {
        assert!(matches!(rx.recv().unwrap(), Outcome::Completed(_)));
    }
    // Recovered: a single request is admitted again.
    assert!(matches!(
        fabric.submit(model, vec![0.0; 4]).unwrap(),
        Submission::Enqueued(_)
    ));
    fabric.shutdown();
}

#[test]
fn identical_concurrent_requests_collapse_into_one_execution() {
    // Gate the executors closed so the leader stays in flight, then
    // submit K identical payloads: one execution, K personalized
    // responses (router-level dedup / response memoization).
    let cfg = FabricConfig { time_scale: 0.0, ..Default::default() };
    let gate = Gate::closed_gate();
    let fabric = place(&cfg, Some(Arc::clone(&gate)));
    let payload = vec![0.5; 64];
    let k = 8u64;
    let mut rxs = Vec::new();
    for _ in 0..k {
        match fabric.submit("lenet", payload.clone()).unwrap() {
            Submission::Enqueued(rx) => rxs.push(rx),
            Submission::Shed => panic!("dedup'd submissions must not shed"),
        }
    }
    assert_eq!(fabric.dedup_hits(), k - 1, "K-1 followers piggyback on the leader");
    gate.open();
    for (i, rx) in rxs.into_iter().enumerate() {
        match rx.recv().expect("every caller must be answered") {
            Outcome::Completed(resp) => assert_eq!(
                resp.id, i as u64,
                "memoized response carries the caller's own request id"
            ),
            Outcome::Failed(e) => panic!("unexpected failure: {e}"),
            Outcome::Shed => panic!("uniform priority never preempts admitted work"),
        }
    }
    let served: u64 = fabric.pod_reports(1.0).iter().map(|r| r.requests).sum();
    assert_eq!(served, 1, "K identical concurrent requests → ONE execution");
    assert_eq!(fabric.fleet_report(1.0).deduped, k - 1);

    // The in-flight entry was unregistered on completion: the same
    // payload now executes afresh.
    match fabric.submit("lenet", payload).unwrap() {
        Submission::Enqueued(rx) => {
            assert!(matches!(rx.recv().unwrap(), Outcome::Completed(_)));
        }
        Submission::Shed => panic!("idle fabric must admit"),
    }
    let served: u64 = fabric.pod_reports(1.0).iter().map(|r| r.requests).sum();
    assert_eq!(served, 2, "post-completion resubmission is a fresh execution");
    fabric.shutdown();
}

#[test]
fn fused_batching_beats_per_item_execution_under_overload() {
    // The tentpole's acceptance property, as a fast smoke: at batch 4 on
    // overloaded simulated pods, fused dispatch (overhead paid once per
    // drained batch) must sustain strictly more completed throughput
    // than the per-item reference path (overhead paid per request).
    use tf2aif::fabric::bench::{run_sweep, BenchConfig};
    let cfg = BenchConfig {
        batches: vec![4],
        rates: vec![20_000.0],
        requests: 200,
        time_scale: 2.0,
        models: vec!["mobilenetv1".into()],
        payload_pool: 8,
        ..Default::default()
    };
    let points = run_sweep(&cfg).unwrap();
    assert_eq!(points.len(), 1);
    let p = &points[0];
    assert_eq!(p.batch, 4);
    assert!(p.fused.completed > 0 && p.per_item.completed > 0);
    assert!(
        p.fused.throughput_rps > p.per_item.throughput_rps * 1.2,
        "fused {:.0} rps must clearly beat per-item {:.0} rps",
        p.fused.throughput_rps,
        p.per_item.throughput_rps
    );
}
