//! Integration: the multi-tenant fabric — per-tenant quotas exact at
//! the burst bound, per-tenant queue shares, weighted-fair draining
//! under a hot tenant, shedding strictly by ascending priority, and
//! typed (never panicking) negative paths.
//!
//! Everything runs on simulated executors with fixed seeds; the test
//! [`Gate`] makes queue contents deterministic (while closed, every pod
//! blocks at the start of its next dispatch), and the pure scenario
//! driver [`tenancy::run_scenarios`] pumps the exact queue/bucket code
//! the fabric runs on with no threads at all.

use std::sync::Arc;

use tf2aif::backend::{Backend, Policy};
use tf2aif::cluster::{paper_testbed, Cluster};
use tf2aif::fabric::sim::{synthetic_catalog_for, Gate};
use tf2aif::fabric::tenancy::{self, parse_tenant_specs, Priority, TenancyError, TenantSpec};
use tf2aif::fabric::{Fabric, FabricConfig, Outcome, Submission, DEFAULT_TENANT};

fn testbed() -> Cluster {
    let mut c = Cluster::new(paper_testbed());
    c.apply_kube_api_extension();
    c
}

/// One-model fabric so replica counts and queue contents are exact.
fn place_one_model(model: &str, cfg: &FabricConfig, gate: Option<Arc<Gate>>) -> Fabric {
    let backend = Backend::new(synthetic_catalog_for(&[model]), Policy::MinLatency);
    Fabric::place_sim(&backend, testbed(), cfg, gate).unwrap()
}

fn spec(id: &str) -> TenantSpec {
    TenantSpec::new(id)
}

fn distinct_payload(i: usize) -> Vec<f32> {
    vec![i as f32; 16]
}

#[test]
fn quota_enforcement_is_exact_at_the_burst_bound() {
    // rate 1/s, burst 5: eight instantaneous submissions admit EXACTLY
    // five (the refill over the microseconds of this loop is ~1e-6 of a
    // token — nowhere near the 1.0 a sixth admission would need).
    let mut metered = spec("metered");
    metered.rate_rps = Some(1.0);
    metered.burst = 5.0;
    let cfg = FabricConfig {
        time_scale: 0.0,
        tenants: vec![metered],
        dedup: false,
        ..Default::default()
    };
    let fabric = place_one_model("lenet", &cfg, None);
    let mut admitted = Vec::new();
    let mut shed = 0usize;
    for i in 0..8 {
        match fabric.submit_as("metered", "lenet", distinct_payload(i)).unwrap() {
            Submission::Enqueued(rx) => admitted.push(rx),
            Submission::Shed => shed += 1,
        }
    }
    assert_eq!(admitted.len(), 5, "exactly the burst admits");
    assert_eq!(shed, 3);
    for rx in admitted {
        assert!(matches!(rx.recv().unwrap(), Outcome::Completed(_)));
    }
    let reports = fabric.tenant_reports();
    let metered = reports.iter().find(|t| t.id == "metered").unwrap();
    assert_eq!(
        (metered.submitted, metered.admitted, metered.completed),
        (8, 5, 5)
    );
    assert_eq!(metered.shed_quota, 3, "quota sheds are attributed to the tenant");
    assert_eq!(metered.shed_capacity, 0, "an idle fleet sheds nothing on capacity");
    assert_eq!(fabric.quota_shed_total(), 3);
    // Quota sheds are policy, not pressure: nothing reached the
    // per-model capacity-shed counter the autoscaler watches.
    assert!(fabric.shed_by_model().is_empty());
    fabric.shutdown();
}

#[test]
fn per_tenant_share_caps_queue_occupancy_so_hot_cannot_starve() {
    // One pod (replicas 1, worker 1, max_batch 1), queue bound 16; the
    // hog tenant may hold at most 25% = 4 slots.  A sacrificial default
    // request occupies the worker behind the closed gate, so queue
    // contents are exact.
    let mut hog = spec("hog");
    hog.max_queue_share = 0.25;
    let cfg = FabricConfig {
        time_scale: 0.0,
        queue_capacity: 16,
        max_batch: 1,
        workers: 1,
        replicas_per_model: 1,
        dedup: false,
        tenants: vec![hog],
        ..Default::default()
    };
    let gate = Gate::closed_gate();
    let fabric = place_one_model("lenet", &cfg, Some(Arc::clone(&gate)));
    let mut pending = Vec::new();
    // Occupy the worker so nothing drains from the queue.
    match fabric.submit("lenet", distinct_payload(9000)).unwrap() {
        Submission::Enqueued(rx) => pending.push(rx),
        Submission::Shed => panic!("idle fabric must admit"),
    }
    std::thread::sleep(std::time::Duration::from_millis(30));

    // The hog floods 20: exactly 4 (its share of 16) may queue.
    let mut hog_admitted = 0usize;
    let mut hog_shed = 0usize;
    for i in 0..20 {
        match fabric.submit_as("hog", "lenet", distinct_payload(i)).unwrap() {
            Submission::Enqueued(rx) => {
                hog_admitted += 1;
                pending.push(rx);
            }
            Submission::Shed => hog_shed += 1,
        }
    }
    assert_eq!(hog_admitted, 4, "the share cap bounds the hog to 25% of the queue");
    assert_eq!(hog_shed, 16);

    // The rest of the queue is still open to other tenants: the default
    // tenant admits 12 more (16 − 4), and only then sheds.
    let mut default_admitted = 0usize;
    for i in 100..120 {
        match fabric.submit("lenet", distinct_payload(i)).unwrap() {
            Submission::Enqueued(rx) => {
                default_admitted += 1;
                pending.push(rx);
            }
            Submission::Shed => {}
        }
    }
    assert_eq!(
        default_admitted, 12,
        "a hot tenant at its share cap cannot starve the rest of the queue"
    );

    gate.open();
    for rx in pending {
        assert!(matches!(rx.recv().unwrap(), Outcome::Completed(_)));
    }
    let reports = fabric.tenant_reports();
    let hog = reports.iter().find(|t| t.id == "hog").unwrap();
    assert_eq!((hog.admitted, hog.completed, hog.shed_capacity), (4, 4, 16));
    fabric.shutdown();
}

#[test]
fn weighted_fair_drain_hits_weights_within_tolerance() {
    // The deterministic scenario driver: tenants weighted 5:3:1, the
    // weight-1 tenant offering 10× everyone else's load, every lane
    // kept backlogged, batches executed on a seeded SimPod.  Drain
    // shares must land within 10% of the configured weights — and
    // reproduce exactly under the same seed.
    let v = tenancy::run_scenarios(0x7E4A);
    assert!(
        v.fair_share_within_tolerance,
        "weighted-fair drain off by {:.1}% (> 10%) over {:?}",
        v.max_share_error * 100.0,
        v.served_per_lane
    );
    let again = tenancy::run_scenarios(0x7E4A);
    assert_eq!(v.served_per_lane, again.served_per_lane, "fixed seed → fixed outcome");
    // The guarantee holds across seeds, not just a lucky one.
    for seed in [1u64, 42, 0xBEEF] {
        let v = tenancy::run_scenarios(seed);
        assert!(
            v.fair_share_within_tolerance,
            "seed {seed}: share error {:.3}",
            v.max_share_error
        );
        assert!(v.quota_exact, "seed {seed}");
        assert!(v.shed_priority_ordered, "seed {seed}");
    }
}

#[test]
fn shedding_preempts_strictly_by_ascending_priority() {
    // One pod, queue bound 6, gate closed, one sacrificial request
    // occupying the worker.  Fill with 4 low + 2 standard, then push
    // high-priority work: evictions must take ALL lows (newest first)
    // before ANY standard, never touch high, and the callers of the
    // evicted requests must receive an explicit Shed — not silence.
    let mut low = spec("low");
    low.priority = Priority::Low;
    let mut std_t = spec("std");
    std_t.priority = Priority::Standard;
    let mut high = spec("high");
    high.priority = Priority::High;
    let cfg = FabricConfig {
        time_scale: 0.0,
        queue_capacity: 6,
        max_batch: 1,
        workers: 1,
        replicas_per_model: 1,
        dedup: false,
        tenants: vec![low, std_t, high],
        ..Default::default()
    };
    let gate = Gate::closed_gate();
    let fabric = place_one_model("lenet", &cfg, Some(Arc::clone(&gate)));
    let sacrificial = match fabric.submit("lenet", distinct_payload(9000)).unwrap() {
        Submission::Enqueued(rx) => rx,
        Submission::Shed => panic!("idle fabric must admit"),
    };
    std::thread::sleep(std::time::Duration::from_millis(30));

    let submit = |tenant: &str, i: usize| match fabric
        .submit_as(tenant, "lenet", distinct_payload(i))
        .unwrap()
    {
        Submission::Enqueued(rx) => rx,
        Submission::Shed => panic!("{tenant} request {i} must be admitted"),
    };
    let low_rxs: Vec<_> = (0..4).map(|i| submit("low", i)).collect();
    let std_rxs: Vec<_> = (10..12).map(|i| submit("std", i)).collect();

    // Six high pushes: 4 preempt lows, 2 preempt standards.
    let mut high_rxs = Vec::new();
    for i in 20..26 {
        high_rxs.push(submit("high", i));
    }
    // The queue now holds only high work: a 7th high submission sheds at
    // admission (equal priority never preempts equal priority)…
    assert!(matches!(
        fabric.submit_as("high", "lenet", distinct_payload(26)).unwrap(),
        Submission::Shed
    ));
    // …and so does new low/standard work.
    assert!(matches!(
        fabric.submit_as("low", "lenet", distinct_payload(27)).unwrap(),
        Submission::Shed
    ));

    // Every preempted caller got an explicit Shed on its channel.
    for rx in low_rxs {
        assert!(
            matches!(rx.recv().unwrap(), Outcome::Shed),
            "low-priority work must have been preempted"
        );
    }
    for rx in std_rxs {
        assert!(
            matches!(rx.recv().unwrap(), Outcome::Shed),
            "standard work preempted only after every low was gone"
        );
    }

    let reports = fabric.tenant_reports();
    let by_id = |id: &str| reports.iter().find(|t| t.id == id).unwrap().clone();
    assert_eq!(by_id("low").preempted, 4, "all four lows preempted");
    assert_eq!(by_id("std").preempted, 2, "both standards preempted");
    assert_eq!(by_id("high").preempted, 0, "the top class is never evicted");
    assert_eq!(by_id("high").shed_capacity, 1, "the 7th high shed at admission");
    assert_eq!(fabric.preempted_total(), 6);

    // Drain: every high request completes.
    gate.open();
    assert!(matches!(sacrificial.recv().unwrap(), Outcome::Completed(_)));
    for rx in high_rxs {
        assert!(matches!(rx.recv().unwrap(), Outcome::Completed(_)));
    }
    assert_eq!(by_id("high").completed, 0, "snapshot taken before drain");
    let after = fabric.tenant_reports();
    assert_eq!(
        after.iter().find(|t| t.id == "high").unwrap().completed,
        6,
        "every admitted high request completed"
    );
    fabric.shutdown();
}

#[test]
fn preemption_counts_as_shed_in_run_accounting() {
    // End-to-end accounting invariant under preemption: completed +
    // failed + shed == submitted, with preempted requests landing in
    // `shed` (explicit), never in `failed` and never silently dropped.
    let mut low = spec("low");
    low.priority = Priority::Low;
    let mut high = spec("high");
    high.priority = Priority::High;
    let cfg = FabricConfig {
        time_scale: 0.0,
        queue_capacity: 4,
        max_batch: 1,
        workers: 1,
        replicas_per_model: 1,
        dedup: false,
        tenants: vec![low, high],
        ..Default::default()
    };
    let gate = Gate::closed_gate();
    let fabric = place_one_model("lenet", &cfg, Some(Arc::clone(&gate)));
    let mut rxs = Vec::new();
    let mut sync_shed = 0usize;
    match fabric.submit("lenet", distinct_payload(9000)).unwrap() {
        Submission::Enqueued(rx) => rxs.push(rx),
        Submission::Shed => panic!("idle fabric must admit"),
    }
    std::thread::sleep(std::time::Duration::from_millis(30));
    for i in 0..4 {
        match fabric.submit_as("low", "lenet", distinct_payload(i)).unwrap() {
            Submission::Enqueued(rx) => rxs.push(rx),
            Submission::Shed => sync_shed += 1,
        }
    }
    for i in 10..16 {
        match fabric.submit_as("high", "lenet", distinct_payload(i)).unwrap() {
            Submission::Enqueued(rx) => rxs.push(rx),
            Submission::Shed => sync_shed += 1,
        }
    }
    gate.open();
    let mut completed = 0usize;
    let mut preempted = 0usize;
    for rx in rxs {
        match rx.recv().expect("every admitted caller is answered") {
            Outcome::Completed(_) => completed += 1,
            Outcome::Shed => preempted += 1,
            Outcome::Failed(e) => panic!("unexpected failure: {e}"),
        }
    }
    assert_eq!(
        completed + preempted + sync_shed,
        11,
        "all 11 submissions accounted: served, preempted, or shed at admission"
    );
    assert_eq!(preempted, 4, "the four lows were preempted by the six highs");
    assert_eq!(fabric.shed_total() as usize, preempted + sync_shed);
    fabric.shutdown();
}

#[test]
fn negative_paths_are_typed_errors_never_panics() {
    // Malformed specs.
    assert!(matches!(
        parse_tenant_specs("gold:w", None, 1.0),
        Err(TenancyError::Malformed { .. })
    ));
    assert!(matches!(
        parse_tenant_specs("gold:p=urgent", None, 1.0),
        Err(TenancyError::Malformed { .. })
    ));
    assert_eq!(parse_tenant_specs("", None, 1.0), Err(TenancyError::EmptySpec));
    // Quota of zero.
    assert_eq!(
        parse_tenant_specs("gold:rate=0", None, 1.0),
        Err(TenancyError::ZeroQuota("gold".into()))
    );
    // …also when it arrives programmatically, at spawn time.
    let mut broken = spec("broken");
    broken.rate_rps = Some(0.0);
    let cfg =
        FabricConfig { time_scale: 0.0, tenants: vec![broken], ..Default::default() };
    let backend = Backend::new(synthetic_catalog_for(&["lenet"]), Policy::MinLatency);
    let err = Fabric::place_sim(&backend, testbed(), &cfg, None).unwrap_err();
    assert!(matches!(
        err.downcast_ref::<TenancyError>(),
        Some(TenancyError::ZeroQuota(id)) if id == "broken"
    ));

    // Unknown tenant id on a request.
    let cfg = FabricConfig { time_scale: 0.0, ..Default::default() };
    let fabric = place_one_model("lenet", &cfg, None);
    let err = fabric.submit_as("nobody", "lenet", distinct_payload(0)).unwrap_err();
    assert!(matches!(
        err.downcast_ref::<TenancyError>(),
        Some(TenancyError::UnknownTenant(id)) if id == "nobody"
    ));
    // The fabric is unharmed: the default tenant still serves.
    match fabric.submit_as(DEFAULT_TENANT, "lenet", distinct_payload(1)).unwrap() {
        Submission::Enqueued(rx) => {
            assert!(matches!(rx.recv().unwrap(), Outcome::Completed(_)));
        }
        Submission::Shed => panic!("idle fabric must admit"),
    }
    fabric.shutdown();
}

#[test]
fn hot_tenant_flood_cannot_starve_a_cold_tenant_end_to_end() {
    // Full-fabric fairness under a real 10:1 flood, no gate: one slow
    // pod (heavy model, doubled simulated latency), tiny queue, equal
    // weights, each tenant capped at half the queue.  The hot tenant
    // offers 10× the cold tenant's traffic; without the tenancy layer
    // it would own the whole queue and completions would track offered
    // load (~10:1).  With it, service stays near parity: the share cap
    // bounds the hot tenant's occupancy and the weighted-fair drain
    // serves both lanes evenly while backlogged.
    let mut hot = spec("hot");
    hot.max_queue_share = 0.5;
    let mut cold = spec("cold");
    cold.max_queue_share = 0.5;
    let cfg = FabricConfig {
        time_scale: 2.0,
        queue_capacity: 8,
        max_batch: 2,
        workers: 1,
        replicas_per_model: 1,
        dedup: false,
        tenants: vec![hot, cold],
        ..Default::default()
    };
    let fabric = place_one_model("inceptionv4", &cfg, None);
    let mix = tf2aif::workload::TenantMix::new(&[
        ("hot".to_string(), 10),
        ("cold".to_string(), 1),
    ])
    .unwrap();
    let run = fabric
        .run_tenants(
            300,
            tf2aif::workload::Arrival::Poisson { rps: 50_000.0 },
            13,
            &mix,
        )
        .unwrap();
    assert!(run.fully_accounted());
    assert!(
        run.shed > run.completed,
        "the flood must deeply overload the pod (shed {} vs completed {})",
        run.shed,
        run.completed
    );
    let reports = fabric.tenant_reports();
    let hot = reports.iter().find(|t| t.id == "hot").unwrap();
    let cold = reports.iter().find(|t| t.id == "cold").unwrap();
    assert!(hot.completed > 0 && cold.completed > 0, "nobody is starved outright");
    assert!(
        hot.completed <= 3 * cold.completed,
        "10:1 offered load must NOT become 10:1 service — fairness holds it near \
         parity (hot {} vs cold {})",
        hot.completed,
        cold.completed
    );
    assert!(
        hot.shed_capacity > cold.shed_capacity,
        "the surplus is shed from the tenant that offered it"
    );
    fabric.shutdown();
}
