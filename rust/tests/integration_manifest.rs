//! Integration: the declarative config plane end to end.
//!
//! Locks the shipped worked-example manifests (`configs/deployment.toml`
//! → `configs/deployment_v2.toml`) against the checked-in golden plan,
//! proves canonical rendering ignores formatting, converges a live
//! deployment mid-traffic under the conservation identity, and runs the
//! same scenario verdicts CI's manifest-converge job gates on.

use tf2aif::manifest::canonical::{content_hash, render, render_json};
use tf2aif::manifest::diff::diff;
use tf2aif::manifest::reconcile::{
    deploy_manifest_sim, drive, reconcile, run_scenarios, settle, DrivePhase,
};
use tf2aif::manifest::DeploymentManifest;

const V1: &str = include_str!("../../configs/deployment.toml");
const V2: &str = include_str!("../../configs/deployment_v2.toml");
const PLAN_GOLDEN: &str = include_str!("golden/manifest_plan_v1_v2.json");

#[test]
fn shipped_manifests_differ_to_the_golden_plan() {
    let v1 = DeploymentManifest::parse(V1).unwrap();
    let v2 = DeploymentManifest::parse(V2).unwrap();
    assert_eq!(v1.version, 1);
    assert_eq!(v2.version, 2);
    let plan = diff(&v1, &v2);
    let rendered = format!("{}\n", render_json(&plan.to_json()));
    assert_eq!(
        rendered, PLAN_GOLDEN,
        "v1→v2 plan drifted from rust/tests/golden/manifest_plan_v1_v2.json"
    );
    assert_eq!(plan.rejected_count(), 0, "{plan:?}");
}

#[test]
fn canonical_rendering_ignores_formatting_of_the_shipped_manifest() {
    let v1 = DeploymentManifest::parse(V1).unwrap();
    // Stripping every comment and blank line must not change meaning.
    let stripped: String = V1
        .lines()
        .filter(|l| !l.trim_start().starts_with('#') && !l.trim().is_empty())
        .map(|l| format!("{l}\n"))
        .collect();
    let again = DeploymentManifest::parse(&stripped).unwrap();
    assert_eq!(render(&v1), render(&again));
    assert_eq!(content_hash(&v1), content_hash(&again));
}

#[test]
fn apply_over_live_traffic_conserves_and_reapply_is_noop() {
    let v1 = DeploymentManifest::parse(V1).unwrap();
    let v2 = DeploymentManifest::parse(V2).unwrap();
    let plan = diff(&v1, &v2);
    let mut orch = deploy_manifest_sim(&v1, 0xBEEF).unwrap();
    assert_eq!(orch.applied_generation(), 1);
    let tenants: Vec<String> = v1.tenants.iter().map(|t| t.id.clone()).collect();
    let mut pending = Vec::new();

    let pre = drive(&mut orch, 60, 1, &tenants, &mut pending).unwrap();
    assert!(!pending.is_empty(), "no admitted work in flight before the apply");

    // Converge v1 → v2 while phase-one receivers are still outstanding.
    let rep = reconcile(&mut orch, &plan).unwrap();
    assert!(!rep.applied.is_empty(), "{rep:?}");
    assert!(rep.rejected.is_empty(), "{rep:?}");
    assert!(rep.replanned, "objective change must replan: {rep:?}");
    assert_eq!(orch.applied_generation(), 2);

    let post = drive(&mut orch, 60, 2, &tenants, &mut pending).unwrap();
    let mut total = DrivePhase::default();
    total.absorb(&pre);
    total.absorb(&post);
    settle(&mut pending, &mut total);
    assert!(total.fully_accounted(), "{total:?}");
    assert_eq!(total.failed, 0, "admitted work was lost across the apply: {total:?}");

    // Re-apply v2: empty diff, reconcile mutates nothing.
    let replan = diff(&v2, &v2);
    assert!(replan.is_noop(), "{replan:?}");
    let reapply = reconcile(&mut orch, &replan).unwrap();
    assert!(reapply.is_noop(), "{reapply:?}");
    assert_eq!(orch.applied_generation(), 2);
    orch.shutdown();
}

#[test]
fn scenario_verdicts_hold_across_seeds() {
    for seed in [3u64, 0xDEAD] {
        let v = run_scenarios(seed).unwrap();
        assert!(
            v.roundtrip_stable
                && v.plan_matches
                && v.quota_edit_live
                && v.converge_accounted
                && v.no_lost_admitted
                && v.reapply_noop
                && v.generation_tracks,
            "seed {seed}: {v:?}"
        );
    }
}
