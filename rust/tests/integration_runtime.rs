//! Integration: artifacts → PJRT runtime → numeric parity with the python
//! build (the cross-layer contract of the whole architecture).
//!
//! Requires `make artifacts` to have run; tests skip gracefully when the
//! artifacts directory is absent so `cargo test` stays usable mid-setup.

use std::sync::Arc;

use tf2aif::artifact::{self, Artifact};
use tf2aif::runtime::{load_verified, Engine};

fn have_artifacts() -> bool {
    std::path::Path::new("artifacts/lenet_CPU/manifest.json").exists()
}

#[test]
fn lenet_all_variants_match_python_fixtures() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let engine = Engine::cpu().unwrap();
    for variant in ["AGX", "ARM", "CPU", "ALVEO", "GPU", "CPU_TF", "GPU_TF"] {
        let a = Arc::new(Artifact::load(format!("artifacts/lenet_{variant}")).unwrap());
        let (_, delta) = load_verified(&engine, &a).unwrap();
        // Same HLO, same inputs, same XLA backend as the python jit —
        // parity should be at float-noise level.
        assert!(delta < 1e-3, "lenet_{variant}: fixture delta {delta}");
    }
}

#[test]
fn mobilenet_int8_and_bf16_parity() {
    if !have_artifacts() {
        return;
    }
    let engine = Engine::cpu().unwrap();
    // INT8: integer accumulation is exact, only the f32 epilogue can
    // drift → tight bound.  bf16: XLA may fuse differently than the
    // python jit, re-rounding intermediates → bf16-scale bound.
    for (variant, tol) in [("ARM", 1e-2), ("GPU", 0.1)] {
        let a = Arc::new(Artifact::load(format!("artifacts/mobilenetv1_{variant}")).unwrap());
        let (model, delta) = load_verified(&engine, &a).unwrap();
        assert!(delta < tol, "mobilenetv1_{variant}: delta {delta}");
        assert_eq!(model.output_elems, 200);
    }
}

#[test]
fn infer_validates_input_shape() {
    if !have_artifacts() {
        return;
    }
    let engine = Engine::cpu().unwrap();
    let a = Arc::new(Artifact::load("artifacts/lenet_CPU").unwrap());
    let model = engine.load(&a).unwrap();
    assert!(model.infer(&[0.0; 3]).is_err(), "wrong input size must error");
    assert!(model.infer(&vec![0.0; 32 * 32]).is_ok());
}

#[test]
fn unload_frees_slot_and_later_infer_fails() {
    if !have_artifacts() {
        return;
    }
    let engine = Engine::cpu().unwrap();
    let a = Arc::new(Artifact::load("artifacts/lenet_CPU").unwrap());
    let model = engine.load(&a).unwrap();
    let clone = model.clone();
    model.unload();
    assert!(clone.infer(&vec![0.0; 32 * 32]).is_err(), "unloaded slot must error");
}

#[test]
fn engine_is_shared_across_threads() {
    if !have_artifacts() {
        return;
    }
    let engine = Engine::cpu().unwrap();
    let a = Arc::new(Artifact::load("artifacts/lenet_CPU").unwrap());
    let model = engine.load(&a).unwrap();
    let fixtures = a.load_fixtures().unwrap();
    let handles: Vec<_> = (0..4)
        .map(|_| {
            let m = model.clone();
            let fx = fixtures[0].clone();
            std::thread::spawn(move || {
                for _ in 0..5 {
                    let out = m.infer(&fx.input).unwrap();
                    assert_eq!(out.len(), fx.expected.len());
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
}

#[test]
fn scan_finds_full_matrix() {
    if !have_artifacts() {
        return;
    }
    let arts = artifact::scan("artifacts").unwrap();
    assert!(arts.len() >= 20, "expected ≥20 artifacts, got {}", arts.len());
    // Every Table I variant present for every Table III model.
    for model in ["lenet", "mobilenetv1", "resnet50", "inceptionv4"] {
        for variant in ["AGX", "ARM", "CPU", "ALVEO", "GPU"] {
            assert!(
                arts.iter()
                    .any(|a| a.manifest.model == model && a.manifest.variant == variant),
                "missing {model}_{variant}"
            );
        }
    }
}

#[test]
fn manifest_stats_are_sane() {
    if !have_artifacts() {
        return;
    }
    for a in artifact::scan("artifacts").unwrap() {
        let m = &a.manifest;
        assert!(m.gflops > 0.0, "{}", m.id());
        assert!(m.param_count > 0);
        assert_eq!(m.input_shape.len(), 4, "NHWC");
        assert_eq!(m.output_shape[1] as u64 % 10, 0, "10 or 200 classes");
        let weights = a.load_weights().unwrap();
        assert_eq!(weights.total_bytes() as u64, m.weights_bytes);
        if m.mode == "int8" {
            assert!(
                m.params.iter().any(|p| p.name.ends_with("/wq")),
                "{} int8 without quantized weights",
                m.id()
            );
        }
    }
}
