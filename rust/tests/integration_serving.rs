//! Integration: serving stack — deploy, client runs, batching under load,
//! failure injection, metrics accounting.

use std::sync::Arc;

use tf2aif::artifact::Artifact;
use tf2aif::client::{Client, ClientConfig};
use tf2aif::runtime::Engine;
use tf2aif::serving::{
    AifServer, BatcherConfig, ImageClassify, PrePost, Prediction, Request, ServerHandle,
};
use tf2aif::util::rng::Rng;
use tf2aif::workload::{image_like, Arrival};

fn have_artifacts() -> bool {
    std::path::Path::new("artifacts/lenet_CPU/manifest.json").exists()
}

fn deploy(variant: &str) -> Option<Arc<AifServer>> {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return None;
    }
    let engine = Engine::cpu().unwrap();
    let a = Arc::new(Artifact::load(format!("artifacts/lenet_{variant}")).unwrap());
    Some(Arc::new(AifServer::deploy(&engine, &a, Arc::new(ImageClassify)).unwrap()))
}

#[test]
fn closed_loop_client_collects_full_series() {
    let Some(server) = deploy("CPU") else { return };
    let client = Client::new(Arc::clone(&server));
    let run = client
        .run(&ClientConfig { requests: 40, arrival: Arrival::ClosedLoop, seed: 1 })
        .unwrap();
    assert_eq!(run.service_ms.len(), 40);
    assert_eq!(run.real_compute_ms.len(), 40);
    assert_eq!(run.errors, 0);
    assert!(run.throughput_rps() > 0.0);
    let snap = server.metrics.snapshot();
    assert_eq!(snap.requests, 40);
    assert_eq!(snap.errors, 0);
}

#[test]
fn client_verify_checks_served_predictions() {
    let Some(server) = deploy("ALVEO") else { return };
    let a = Artifact::load("artifacts/lenet_ALVEO").unwrap();
    let client = Client::new(Arc::clone(&server));
    assert_eq!(client.verify(&a).unwrap(), 4);
}

#[test]
fn service_latency_is_reproducible_with_seed() {
    let Some(server) = deploy("GPU") else { return };
    let client = Client::new(Arc::clone(&server));
    server.reseed(99);
    let r1 = client
        .run(&ClientConfig { requests: 10, arrival: Arrival::ClosedLoop, seed: 5 })
        .unwrap();
    server.reseed(99);
    let r2 = client
        .run(&ClientConfig { requests: 10, arrival: Arrival::ClosedLoop, seed: 5 })
        .unwrap();
    assert_eq!(r1.service_ms.samples(), r2.service_ms.samples());
}

#[test]
fn batched_loop_serves_burst_without_loss() {
    let Some(server) = deploy("CPU") else { return };
    let handle = ServerHandle::spawn(
        Arc::clone(&server),
        BatcherConfig { max_batch: 4, workers: 3 },
    );
    let mut rng = Rng::new(2);
    let pending: Vec<_> = (0..100)
        .map(|i| {
            handle.submit(Request { id: i, payload: image_like(&mut rng, 32, 32, 1).into() })
        })
        .collect();
    let mut ids = Vec::new();
    for (i, rx) in pending.into_iter().enumerate() {
        let resp = rx.recv().unwrap().unwrap();
        assert_eq!(resp.id, i as u64, "responses must be matched to requests");
        ids.push(resp.id);
        assert!(resp.prediction.class < 10);
    }
    assert_eq!(ids.len(), 100);
    handle.shutdown();
    assert_eq!(server.metrics.snapshot().requests, 100);
}

#[test]
fn failure_injection_bad_input_is_counted_not_fatal() {
    let Some(server) = deploy("CPU") else { return };
    // Payload of the wrong size: preprocess passes it through, infer must
    // reject it, metrics must count it, server must keep serving.
    let bad = Request { id: 1, payload: vec![0.0; 7].into() };
    assert!(server.handle(&bad).is_err());
    assert_eq!(server.metrics.snapshot().errors, 1);
    let mut rng = Rng::new(3);
    let good = Request { id: 2, payload: image_like(&mut rng, 32, 32, 1).into() };
    assert!(server.handle(&good).is_ok(), "server must survive bad requests");
}

#[test]
fn custom_prepost_interface_is_honored() {
    // The paper's user interface: ~100 lines of custom pre/post. Here: a
    // scale-by-2 preprocess and a top-1-with-softmax postprocess.
    struct Custom;
    impl PrePost for Custom {
        fn preprocess(&self, raw: &[f32]) -> Vec<f32> {
            raw.iter().map(|v| v * 2.0).collect()
        }
        fn postprocess(&self, logits: &[f32]) -> Prediction {
            let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let exps: Vec<f32> = logits.iter().map(|l| (l - m).exp()).collect();
            let sum: f32 = exps.iter().sum();
            let (class, score) = exps
                .iter()
                .enumerate()
                .fold((0, 0f32), |acc, (i, &e)| if e > acc.1 { (i, e) } else { acc });
            Prediction { class, score: score / sum }
        }
    }
    if !have_artifacts() {
        return;
    }
    let engine = Engine::cpu().unwrap();
    let a = Arc::new(Artifact::load("artifacts/lenet_CPU").unwrap());
    let server = AifServer::deploy(&engine, &a, Arc::new(Custom)).unwrap();
    let mut rng = Rng::new(4);
    let resp = server
        .handle(&Request { id: 0, payload: image_like(&mut rng, 32, 32, 1).into() })
        .unwrap();
    assert!(resp.prediction.score > 0.0 && resp.prediction.score <= 1.0, "softmax");
}

#[test]
fn native_variant_uses_native_cost_model() {
    let Some(accel) = deploy("CPU") else { return };
    let Some(native) = deploy("CPU_TF") else { return };
    assert!(!accel.is_native());
    assert!(native.is_native());
    let mut rng = Rng::new(5);
    let img: std::sync::Arc<[f32]> = image_like(&mut rng, 32, 32, 1).into();
    let a = accel.handle(&Request { id: 0, payload: img.clone() }).unwrap();
    let n = native.handle(&Request { id: 0, payload: img }).unwrap();
    assert!(
        n.service_ms > a.service_ms * 1.5,
        "native {} vs accel {}",
        n.service_ms,
        a.service_ms
    );
}
