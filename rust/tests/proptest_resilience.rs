//! Property-style tests for the chaos fabric — randomized inputs under
//! fixed seeds (deterministic, reproducible), checking the load-bearing
//! resilience invariant from both directions:
//!
//! - Threaded fabric: K-way deduplicated submissions whose shared work
//!   item is seized by a pod crash yield exactly K terminal verdicts —
//!   every follower is notified, nobody hangs, nobody hears twice.
//! - Virtual time: randomly generated fault storms (crashes,
//!   stragglers, partitions, site flaps) over random two-site scenarios
//!   conserve every request and replay byte-identically.

use std::sync::Arc;

use tf2aif::backend::{Backend, Policy};
use tf2aif::cluster::{paper_testbed, Cluster};
use tf2aif::fabric::des::{
    run_des, DesConfig, DesModel, DesScenario, DesSite,
};
use tf2aif::fabric::sim::{synthetic_catalog, Gate};
use tf2aif::fabric::{
    BreakerConfig, BrownoutConfig, Fabric, FabricConfig, Fault, FaultPlan, HedgePolicy,
    Outcome, ResilienceConfig, RetryPolicy, Submission,
};
use tf2aif::util::rng::Rng;
use tf2aif::workload::RateCurve;

fn place(cfg: &FabricConfig, gate: Option<Arc<Gate>>) -> Fabric {
    let backend = Backend::new(synthetic_catalog(), Policy::MinLatency);
    let mut cluster = Cluster::new(paper_testbed());
    cluster.apply_kube_api_extension();
    Fabric::place_sim(&backend, cluster, cfg, gate).unwrap()
}

#[test]
fn crashed_dedup_group_yields_exactly_one_verdict_per_follower() {
    // One gated lenet replica.  A pin submission blocks the worker
    // in-flight; D distinct requests plus one K-way deduplicated group
    // queue up behind it.  Crashing the pod must hand every queued
    // waiter — including all K dedup followers sharing one work item —
    // exactly one terminal verdict, while the in-flight pin completes
    // normally once the gate opens.  Randomized over D, K and the
    // retry/breaker policies; the routing itself is deterministic.
    for seed in 0..6u64 {
        let mut rng = Rng::new(0xC8A5 ^ seed.wrapping_mul(0x9E3779B97F4A7C15));
        let d = 1 + rng.below(5);
        let k = 2 + rng.below(5);
        let retry_on = rng.below(2) == 1;
        let breaker_on = rng.below(2) == 1;
        let gate = Gate::closed_gate();
        let cfg = FabricConfig {
            time_scale: 0.0,
            replicas_per_model: 1,
            queue_capacity: 16,
            workers: 1,
            resilience: ResilienceConfig {
                retry: if retry_on { Some(RetryPolicy::default()) } else { None },
                breaker: if breaker_on { Some(BreakerConfig::default()) } else { None },
                ..Default::default()
            },
            ..Default::default()
        };
        let fabric = place(&cfg, Some(Arc::clone(&gate)));

        let Submission::Enqueued(pin) = fabric.submit("lenet", vec![-1.0; 8]).unwrap()
        else {
            panic!("seed {seed}: idle fabric must admit the pin");
        };
        gate.await_blocked(1);

        let mut queued = Vec::new();
        for i in 0..d {
            match fabric.submit("lenet", vec![i as f32 + 1.0; 8]).unwrap() {
                Submission::Enqueued(rx) => queued.push(rx),
                Submission::Shed => panic!("seed {seed}: queue has room for item {i}"),
            }
        }
        let mut followers = Vec::new();
        for j in 0..k {
            match fabric.submit("lenet", vec![999.0; 8]).unwrap() {
                Submission::Enqueued(rx) => followers.push(rx),
                Submission::Shed => panic!("seed {seed}: dedup follower {j} shed"),
            }
        }
        assert_eq!(
            fabric.dedup_hits(),
            (k - 1) as u64,
            "seed {seed}: followers after the first attach to the in-flight entry"
        );

        let idx = fabric.plans().iter().position(|p| p.model == "lenet").unwrap();
        let seized = fabric.inject_pod_crash(idx).unwrap();
        assert_eq!(
            seized,
            d + 1,
            "seed {seed}: the crash seizes the D distinct items plus one dedup work item"
        );
        gate.open();

        assert!(
            matches!(pin.recv().unwrap(), Outcome::Completed(_)),
            "seed {seed}: in-flight work survives the crash of its own pod's queue"
        );
        for (i, rx) in queued.into_iter().chain(followers).enumerate() {
            assert!(
                matches!(rx.recv().unwrap(), Outcome::Failed(_)),
                "seed {seed}: waiter {i} must hear a terminal verdict (no hang)"
            );
            assert!(
                rx.try_recv().is_err(),
                "seed {seed}: waiter {i} must hear exactly once (no double delivery)"
            );
        }

        let fleet = fabric.fleet_report(1.0);
        assert_eq!(fleet.faults_injected, 1, "seed {seed}");
        if retry_on {
            assert_eq!(
                fleet.retries,
                (d + 1) as u64,
                "seed {seed}: each seized work item consumed one retry before failing"
            );
        } else {
            assert_eq!(fleet.retries, 0, "seed {seed}: no retry policy, no retries");
        }
        if breaker_on {
            assert!(
                fleet.breaker_trips >= 1,
                "seed {seed}: the crash force-opens the pod's breaker"
            );
        }
        fabric.shutdown();
    }
}

/// A random but seed-determined two-site scenario carrying a random
/// fault storm: crashes (with and without restart), stragglers,
/// partitions and site flaps at random times, under randomly toggled
/// hedge/breaker/brownout policies (retry always on).
fn random_chaos_scenario(seed: u64) -> DesScenario {
    let mut rng = Rng::new(0xFA17 ^ seed.wrapping_mul(0x9E3779B97F4A7C15));
    let variants = ["GPU", "AGX", "ARM"];
    let sites: Vec<DesSite> = (0..2)
        .map(|i| DesSite {
            name: format!("s{i}"),
            tier: if i == 0 { "cloud".to_string() } else { "edge".to_string() },
            variant: variants[rng.below(variants.len())].to_string(),
            pods: 1 + rng.below(2),
            arrivals: Some(RateCurve::Constant { rps: rng.range_f64(10.0, 50.0) }),
            mix: None,
        })
        .collect();
    let mut faults = Vec::new();
    for _ in 0..1 + rng.below(4) {
        let site = format!("s{}", rng.below(2));
        let at_s = rng.range_f64(2.0, 20.0);
        let fault = match rng.below(4) {
            0 => Fault::PodCrash {
                at_s,
                site,
                pod: 0,
                restart_s: if rng.below(2) == 1 {
                    Some(at_s + rng.range_f64(1.0, 8.0))
                } else {
                    None
                },
            },
            1 => Fault::Straggler {
                at_s,
                until_s: at_s + rng.range_f64(1.0, 8.0),
                site,
                factor: rng.range_f64(2.0, 8.0),
            },
            2 => Fault::Partition {
                at_s,
                heal_s: at_s + rng.range_f64(1.0, 6.0),
                a: "s0".to_string(),
                b: "s1".to_string(),
            },
            _ => Fault::SiteFlap {
                at_s,
                recover_s: at_s + rng.range_f64(1.0, 6.0),
                site,
            },
        };
        faults.push(fault);
    }
    let resilience = ResilienceConfig {
        retry: Some(RetryPolicy::default()),
        hedge: if rng.below(2) == 1 { Some(HedgePolicy::default()) } else { None },
        breaker: if rng.below(2) == 1 { Some(BreakerConfig::default()) } else { None },
        brownout: if rng.below(2) == 1 { Some(BrownoutConfig::default()) } else { None },
    };
    DesScenario {
        name: format!("chaos-{seed}"),
        horizon_s: 30.0,
        models: vec![
            DesModel { name: "lenet".to_string(), gflops: 0.001 },
            DesModel { name: "resnet50".to_string(), gflops: 0.168 },
        ],
        sites,
        rtt_ms: vec![vec![0.0, 12.0], vec![12.0, 0.0]],
        trace: None,
        drills: Vec::new(),
        handovers: Vec::new(),
        faults: FaultPlan { name: format!("chaos-plan-{seed}"), faults },
        cfg: DesConfig {
            queue_capacity: 2 + rng.below(14),
            max_batch: 1 + rng.below(8),
            resilience,
            seed: seed.wrapping_add(0xFEE1),
            ..DesConfig::default()
        },
    }
}

#[test]
fn random_fault_storms_conserve_every_request() {
    for seed in 0..6u64 {
        let report = run_des(&random_chaos_scenario(seed)).unwrap();
        assert!(report.submitted > 0, "seed {seed}: load was offered");
        assert!(report.faults_injected > 0, "seed {seed}: the plan must actually fire");
        assert!(
            report.conservation_holds(),
            "seed {seed}: {} submitted != {} completed + {} cached + {} shed \
             + {} quota-shed + {} failed",
            report.submitted,
            report.completed,
            report.cache_hits,
            report.shed,
            report.quota_shed,
            report.failed,
        );
    }
}

#[test]
fn random_fault_storms_replay_byte_identically() {
    for seed in [0u64, 2, 5] {
        let first = run_des(&random_chaos_scenario(seed)).unwrap();
        let second = run_des(&random_chaos_scenario(seed)).unwrap();
        assert_eq!(
            first.canonical_json(),
            second.canonical_json(),
            "seed {seed}: the same storm must replay to identical bytes"
        );
    }
}
