//! Shared bench-harness utilities (no criterion in the vendored set, so
//! the harness is in-repo: warmup + repeated timed runs + summary stats).

use std::time::Instant;

use tf2aif::util::stats::Series;

/// Time `f` `iters` times after `warmup` runs; returns ms per iteration.
pub fn bench_ms<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Series {
    for _ in 0..warmup {
        f();
    }
    let mut s = Series::new();
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        s.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    s
}

/// Pretty one-line summary.
pub fn summarize(name: &str, s: &mut Series) {
    println!(
        "{name:<40} n={:<4} median {:>9.3} ms  p10 {:>9.3}  p90 {:>9.3}  mean {:>9.3}",
        s.len(),
        s.percentile(50.0),
        s.percentile(10.0),
        s.percentile(90.0),
        s.mean(),
    );
}

/// `BENCH_QUICK=1` trims iteration counts (CI-friendly).
pub fn quick() -> bool {
    std::env::var("BENCH_QUICK").map(|v| v == "1").unwrap_or(false)
}
