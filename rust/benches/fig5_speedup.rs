//! Fig. 5 — average latency of TF2AIF's accelerated variants vs native
//! TensorFlow implementations on the same platforms.
//!
//! Paper result: AGX 5.5×, ARM 2.7×, CPU 3.6×, GPU 7.6× average speedup;
//! no ALVEO baseline (TensorFlow has no FPGA backend).  Both graphs run
//! for real on PJRT (different computations: Pallas-kernel path vs the
//! un-folded generic graph); reported means come from the calibrated
//! platform models (DESIGN.md §2).
//!
//! Run: `cargo bench --bench fig5_speedup`.

mod common;

use tf2aif::coordinator::{self, Fig4Options};
use tf2aif::report;
use tf2aif::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let opts = Fig4Options {
        requests: 1000,
        real_requests: if common::quick() { 1 } else { 4 },
        seed: 0xF165,
    };
    let engine = Engine::cpu()?;
    let rows = coordinator::bench_fig5(&engine, "artifacts", &opts)?;

    println!("\nFIG 5 — accelerated vs native TensorFlow (* = simulated platform model)");
    let (h, r) = report::fig5(&rows);
    print!("{}", report::render_table(&h, &r));
    report::write_csv("reports/fig5.csv", &h, &r)?;

    let paper = [("AGX", 5.5), ("ARM", 2.7), ("CPU", 3.6), ("GPU", 7.6)];
    println!("\naverage speedup per platform vs paper:");
    let summary = report::fig5_summary(&rows);
    let mut all_ok = true;
    for (platform, target) in paper {
        let got = summary
            .iter()
            .find(|(p, _)| p == platform)
            .map(|(_, s)| *s)
            .unwrap_or(f64::NAN);
        // Shape tolerance: within ±40% of the paper's average — the
        // substrate differs, the ordering and rough magnitude must not.
        let ok = (got / target - 1.0).abs() < 0.4;
        all_ok &= ok;
        println!(
            "  {platform:<4} measured {got:>5.2}x  paper {target:>4.1}x  — {}",
            if ok { "OK" } else { "OUT OF BAND" }
        );
    }
    // Ordering check: GPU > AGX > CPU > ARM (paper's ranking).
    let get = |p: &str| summary.iter().find(|(q, _)| q == p).unwrap().1;
    let order_ok = get("GPU") > get("AGX") && get("AGX") > get("CPU") && get("CPU") > get("ARM");
    println!(
        "  ranking GPU > AGX > CPU > ARM — {}",
        if order_ok { "OK" } else { "VIOLATED" }
    );
    println!(
        "\noverall: {}",
        if all_ok && order_ok { "Fig. 5 shape reproduced" } else { "deviations present (see above)" }
    );
    Ok(())
}
