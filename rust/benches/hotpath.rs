//! §Perf hot-path bench — quantifies every layer of the serving stack so
//! the optimization log in EXPERIMENTS.md has honest before/after numbers.
//!
//! Measured, per variant (smallest + largest models to bracket):
//!   1. raw PJRT execution (`LoadedModel::infer`) — L2 graph cost,
//!   2. full server handle (preprocess + infer + postprocess + metrics +
//!      cost model) — L3 overhead on top of (1),
//!   3. batched server loop round-trip — queueing machinery overhead,
//!   4. workload generation and JSON manifest parse (coordinator paths).
//!
//! Run: `cargo bench --bench hotpath` — `BENCH_QUICK=1` trims iterations.

mod common;

use std::sync::Arc;

use tf2aif::artifact::Artifact;
use tf2aif::runtime::Engine;
use tf2aif::serving::{AifServer, BatcherConfig, ImageClassify, Request, ServerHandle};
use tf2aif::util::rng::Rng;
use tf2aif::workload::image_like;

fn main() -> anyhow::Result<()> {
    let iters = if common::quick() { 20 } else { 200 };
    let engine = Engine::cpu()?;

    for id in ["lenet_CPU", "mobilenetv1_GPU", "resnet50_AGX"] {
        let Ok(art) = Artifact::load(format!("artifacts/{id}")) else {
            eprintln!("skipping {id}: run `make artifacts`");
            continue;
        };
        let art = Arc::new(art);
        let server = Arc::new(AifServer::deploy(&engine, &art, Arc::new(ImageClassify))?);
        let shape = server.model.input_shape.clone();
        let (h, w, c) = (shape[1], shape[2], shape[3]);
        let mut rng = Rng::new(7);
        let img = image_like(&mut rng, h, w, c);

        println!("\n─ {id} ({} layers, {:.3} GFLOPs)", art.manifest.layers, art.manifest.gflops);

        // 1. Raw PJRT execution.
        let model = server.model.clone();
        let img1 = img.clone();
        let mut s =
            common::bench_ms(3, iters, || {
                std::hint::black_box(model.infer(&img1).unwrap());
            });
        common::summarize("L2 raw infer (PJRT execute)", &mut s);
        let raw_med = s.percentile(50.0);

        // 2. Full server handle.
        let srv = Arc::clone(&server);
        let img2: Arc<[f32]> = img.clone().into();
        let mut n = 0u64;
        let mut s = common::bench_ms(3, iters, || {
            n += 1;
            std::hint::black_box(
                srv.handle(&Request { id: n, payload: Arc::clone(&img2) }).unwrap(),
            );
        });
        common::summarize("L3 server handle (pre+infer+post)", &mut s);
        let handle_med = s.percentile(50.0);
        println!(
            "{:<40} {:.3} ms ({:.1}% of handle)",
            "  → L3 overhead over raw infer",
            handle_med - raw_med,
            (handle_med - raw_med) / handle_med * 100.0
        );

        // 3. Batched server-loop round-trip.
        let handle = ServerHandle::spawn(
            Arc::clone(&server),
            BatcherConfig { max_batch: 8, workers: 1 },
        );
        let img3: Arc<[f32]> = img.clone().into();
        let mut m = 1_000_000u64;
        let mut s = common::bench_ms(3, iters, || {
            m += 1;
            let rx = handle.submit(Request { id: m, payload: Arc::clone(&img3) });
            std::hint::black_box(rx.recv().unwrap().unwrap());
        });
        common::summarize("L3 queued round-trip (1 in flight)", &mut s);
        handle.shutdown();

        // 4. Coordinator-path microbenches.
        let mut s = common::bench_ms(3, iters, || {
            let mut r = Rng::new(9);
            std::hint::black_box(image_like(&mut r, h, w, c));
        });
        common::summarize("workload image_like", &mut s);

        let manifest_src = std::fs::read_to_string(art.dir.join("manifest.json"))?;
        let mut s = common::bench_ms(3, iters, || {
            std::hint::black_box(
                tf2aif::artifact::Manifest::parse(&manifest_src).unwrap(),
            );
        });
        common::summarize("manifest JSON parse", &mut s);
    }

    // 5. Fabric submit→verdict round-trip over zero-work pods — the
    //    router/queue/dedup overhead in isolation (no artifacts needed;
    //    `tf2aif bench --hotpath` is the saturation version of this).
    fabric_roundtrip(iters)?;
    Ok(())
}

fn fabric_roundtrip(iters: usize) -> anyhow::Result<()> {
    use tf2aif::backend::{Backend, Policy};
    use tf2aif::cluster::{paper_testbed, Cluster};
    use tf2aif::fabric::{sim, Fabric, FabricConfig, Outcome, Submission};

    println!("\n─ fabric submit→verdict (NullPod, zero-work executors)");
    for (label, dedup) in [("dedup off", false), ("dedup on", true)] {
        let cfg = FabricConfig {
            queue_capacity: 256,
            max_batch: 16,
            workers: 1,
            replicas_per_model: 1,
            time_scale: 0.0,
            fused: true,
            dedup,
            cache_capacity: 0,
            ..Default::default()
        };
        let backend =
            Backend::new(sim::synthetic_catalog_for(&["mobilenetv1"]), Policy::MinLatency);
        let mut cluster = Cluster::new(paper_testbed());
        cluster.apply_kube_api_extension();
        let fabric = Fabric::place_null(&backend, cluster, &cfg)?;
        let model = fabric.models().first().cloned().expect("placed model");
        let mut k = 0u64;
        let payloads: Vec<Arc<[f32]>> = (0..256)
            .map(|i| {
                let mut p = vec![0.125f32; 64];
                p[0] = i as f32;
                p.into()
            })
            .collect();
        let mut s = common::bench_ms(3, iters.max(100), || {
            k += 1;
            let payload = Arc::clone(&payloads[(k as usize) % payloads.len()]);
            match fabric.submit(&model, payload).unwrap() {
                Submission::Enqueued(rx) => match rx.recv().unwrap() {
                    Outcome::Completed(_) => {}
                    other => panic!("null pod never sheds/fails: {other:?}"),
                },
                Submission::Shed => panic!("closed loop cannot shed"),
            }
        });
        common::summarize(&format!("submit→verdict round-trip ({label})"), &mut s);
        fabric.shutdown();
    }
    Ok(())
}
