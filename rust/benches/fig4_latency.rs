//! Fig. 4 — boxplot of per-request latency for every AI-framework-platform
//! model variant (paper: 1000 requests each).
//!
//! Two channels per variant (DESIGN.md §2): the *service* series is the
//! calibrated platform cost model (what the paper's hardware would
//! report — labelled simulated), the *real* series is actual PJRT
//! execution of the variant's graph on this testbed (numeric truth).
//!
//! Run: `cargo bench --bench fig4_latency` — `BENCH_QUICK=1` for CI.

mod common;

use tf2aif::coordinator::{self, Fig4Options};
use tf2aif::report;
use tf2aif::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let opts = Fig4Options {
        requests: 1000,
        real_requests: if common::quick() { 2 } else { 8 },
        seed: 0xF16_4,
    };
    let engine = Engine::cpu()?;
    let rows = coordinator::bench_fig4(&engine, "artifacts", &opts)?;

    println!("\nFIG 4 — request latency per variant (* = simulated platform model)");
    let (h, r) = report::fig4(&rows);
    print!("{}", report::render_table(&h, &r));
    report::write_csv("reports/fig4.csv", &h, &r)?;

    // Paper-shape checks.
    println!("\nshape checks:");
    let med = |m: &str, v: &str| {
        rows.iter()
            .find(|r| r.model == m && r.variant == v)
            .map(|r| r.service.median)
            .unwrap_or(f64::NAN)
    };
    // 1. Small models: minimal variation across platforms.
    let lenet: Vec<f64> = ["AGX", "ARM", "CPU", "ALVEO", "GPU"]
        .iter()
        .map(|v| med("lenet", v))
        .collect();
    let spread = lenet.iter().fold(f64::MIN, |a, &b| a.max(b))
        - lenet.iter().fold(f64::MAX, |a, &b| a.min(b));
    println!(
        "  LeNet cross-platform spread {:.2} ms (paper: minimal) — {}",
        spread,
        if spread < 5.0 { "OK" } else { "WIDE" }
    );
    // 2. Large models: advanced platforms pull ahead.
    let ok = med("inceptionv4", "GPU") < med("inceptionv4", "ALVEO")
        && med("inceptionv4", "ALVEO") < med("inceptionv4", "AGX")
        && med("inceptionv4", "AGX") < med("inceptionv4", "CPU")
        && med("inceptionv4", "CPU") < med("inceptionv4", "ARM");
    println!(
        "  InceptionV4 ordering GPU < ALVEO < AGX < CPU < ARM — {}",
        if ok { "OK" } else { "VIOLATED" }
    );
    // 3. CPU shows the highest relative variability (context switching).
    let rel_iqr = |v: &str| {
        let r = rows
            .iter()
            .find(|r| r.model == "resnet50" && r.variant == v)
            .unwrap();
        (r.service.q3 - r.service.q1) / r.service.median
    };
    let cpu_iqr = rel_iqr("CPU");
    let others = ["AGX", "ARM", "ALVEO", "GPU"].map(rel_iqr);
    println!(
        "  CPU rel-IQR {:.3} vs max(others) {:.3} — {}",
        cpu_iqr,
        others.iter().fold(0.0f64, |a, &b| a.max(b)),
        if cpu_iqr > others.iter().fold(0.0f64, |a, &b| a.max(b)) { "OK" } else { "VIOLATED" }
    );
    Ok(())
}
