//! Tables I–III — regenerate the paper's three setup tables from the
//! live system (platform registry, cluster config, artifact manifests)
//! and check the invariants the paper states about them.
//!
//! Run: `cargo bench --bench tables`.

mod common;

use tf2aif::artifact;
use tf2aif::cluster::paper_testbed;
use tf2aif::report;

fn main() -> anyhow::Result<()> {
    println!("\nTABLE I — Inference Acceleration Frameworks by Platform and Precision");
    let (h, r) = report::table1();
    print!("{}", report::render_table(&h, &r));
    report::write_csv("reports/table1.csv", &h, &r)?;
    assert_eq!(r.len(), 5, "five AI-framework-platform combinations");

    println!("\nTABLE II — Experimental setup (simulated per DESIGN.md §2)");
    let nodes = paper_testbed();
    let (h, r) = report::table2(&nodes);
    print!("{}", report::render_table(&h, &r));
    report::write_csv("reports/table2.csv", &h, &r)?;
    assert_eq!(nodes.len(), 3, "NE-1, NE-2, FE");

    println!("\nTABLE III — Model characteristics (paper vs this reproduction)");
    let artifacts = artifact::scan("artifacts").unwrap_or_default();
    let (h, r) = report::table3(&artifacts);
    print!("{}", report::render_table(&h, &r));
    report::write_csv("reports/table3.csv", &h, &r)?;

    if !artifacts.is_empty() {
        // Size/FLOPs ordering invariant (Table III): LeNet ≪ MobileNetV1
        // < ResNet50 < InceptionV4.
        let gf = |m: &str| {
            artifacts
                .iter()
                .find(|a| a.manifest.model == m)
                .map(|a| a.manifest.gflops)
                .unwrap_or(f64::NAN)
        };
        let sz = |m: &str| {
            artifacts
                .iter()
                .find(|a| a.manifest.model == m)
                .map(|a| a.manifest.master_size_mb)
                .unwrap_or(f64::NAN)
        };
        let order = ["lenet", "mobilenetv1", "resnet50", "inceptionv4"];
        for w in order.windows(2) {
            assert!(
                gf(w[0]) < gf(w[1]),
                "GFLOPs ordering violated: {} !< {}",
                w[0],
                w[1]
            );
            assert!(
                sz(w[0]) < sz(w[1]),
                "size ordering violated: {} !< {}",
                w[0],
                w[1]
            );
        }
        println!("\nordering invariants (size and GFLOPs monotone across Table III) — OK");
    }
    Ok(())
}
