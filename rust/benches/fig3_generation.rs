//! Fig. 3 — AI service variant generation time (conversion + compose).
//!
//! Regenerates the paper's development-time experiment: for every Table
//! III model × Table I platform, report the conversion time (python-
//! measured: quantization/folding + AOT lowering) and the compose time
//! (measured live: bundle assembly incl. the ALVEO DPU instruction
//! compile).  The paper's shape to reproduce: compose is small and flat,
//! conversion grows with model size, ALVEO prepares slowest.
//!
//! Run: `cargo bench --bench fig3_generation` (artifacts must exist).

mod common;

use tf2aif::artifact::Artifact;
use tf2aif::composer::{self, ComposeOptions};
use tf2aif::coordinator::{MODELS, VARIANTS};
use tf2aif::report::{self, GenRow};

fn main() -> anyhow::Result<()> {
    let iters = if common::quick() { 2 } else { 5 };
    let mut rows = Vec::new();
    for model in MODELS {
        for variant in VARIANTS {
            let dir = format!("artifacts/{model}_{variant}");
            let Ok(art) = Artifact::load(&dir) else {
                eprintln!("skipping {model}_{variant}: run `make artifacts` first");
                continue;
            };
            // Compose measured live, best-of-N to de-noise (bundle
            // assembly + hashing).
            let opts = ComposeOptions::default();
            let mut compose = common::bench_ms(1, iters, || {
                let s = composer::compose_server(&art, &opts).expect("compose");
                std::hint::black_box(s.digest.len());
            });
            // ALVEO conversion includes the Vitis-AI xcompiler substrate
            // (schedule-optimized DPU instruction compile) — measure live.
            let dpu_s = if *variant == "ALVEO" {
                let mut s = common::bench_ms(1, iters, || {
                    let (p, traffic) = tf2aif::composer::dpu::compile_program_optimized(
                        &art.manifest,
                        tf2aif::composer::dpu::DPUCAHX8H,
                    );
                    std::hint::black_box((p.len(), traffic));
                });
                s.percentile(50.0) / 1e3
            } else {
                0.0
            };
            let bundle = composer::compose_server(&art, &opts)?;
            rows.push(GenRow {
                model: model.to_string(),
                variant: variant.to_string(),
                convert_s: art.manifest.convert_time_s + art.manifest.lower_time_s + dpu_s,
                compose_s: compose.percentile(50.0) / 1e3,
                bundle_mb: bundle.total_bytes() as f64 / 1e6,
            });
        }
    }

    println!("\nFIG 3 — variant generation time (convert = python-measured at export)");
    let (h, r) = report::fig3(&rows);
    print!("{}", report::render_table(&h, &r));
    report::write_csv("reports/fig3.csv", &h, &r)?;

    // Shape assertions the paper reports in prose.
    let total = |m: &str| -> f64 {
        rows.iter().filter(|r| r.model == m).map(|r| r.convert_s + r.compose_s).sum()
    };
    let t_lenet = total("lenet");
    let t_incep = total("inceptionv4");
    println!("\nshape checks:");
    println!(
        "  lightweight models faster: lenet {t_lenet:.1}s vs inceptionv4 {t_incep:.1}s — {}",
        if t_lenet < t_incep { "OK" } else { "VIOLATED" }
    );
    // Paper: "the ALVEO version consistently demands the most time for
    // preparation, which delay originates from the Vitis-AI conversion."
    // Compare ALVEO conversion against the other INT8 flows per model
    // (FP32/FP16 variants skip calibration entirely, so the meaningful
    // comparison is within the quantizing flows).
    let mut alveo_slowest = 0;
    let mut checked = 0;
    for model in MODELS {
        let conv = |v: &str| {
            rows.iter()
                .find(|r| r.model == *model && r.variant == v)
                .map(|r| r.convert_s)
        };
        if let (Some(alveo), Some(agx), Some(arm)) =
            (conv("ALVEO"), conv("AGX"), conv("ARM"))
        {
            checked += 1;
            if alveo >= agx && alveo >= arm {
                alveo_slowest += 1;
            }
        }
    }
    println!(
        "  ALVEO slowest of the INT8 conversions (Vitis-AI DPU compile): {alveo_slowest}/{checked} models"
    );
    let grand: f64 = rows.iter().map(|r| r.convert_s + r.compose_s).sum();
    println!(
        "  20 deployment-ready variants in {:.1} s total (paper: ≈10 min on their toolchain)",
        grand
    );
    Ok(())
}
